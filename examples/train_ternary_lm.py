"""Train a ternary (BitNet-b1.58-style QAT) language model, checkpoint it,
and convert the result to RSR serve indices.

Default is a CPU-friendly ~6M-param model for a quick demo; ``--preset 100m``
selects a ~100M-param llama-style config (a few hundred steps — sized for a
real accelerator; on this 1-core container expect hours).

    PYTHONPATH=src python examples/train_ternary_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models import transformer as tfm
from repro.train import data as data_lib
from repro.train.fault import FaultManager
from repro.train.loop import train_state_init, train_step

PRESETS = {
    "demo": ModelConfig(name="demo-ternary-lm", family="dense",
                        num_layers=4, d_model=256, num_heads=4,
                        num_kv_heads=4, d_ff=1024, vocab_size=2048,
                        dtype="float32"),
    "100m": ModelConfig(name="ternary-lm-100m", family="dense",
                        num_layers=12, d_model=768, num_heads=12,
                        num_kv_heads=12, d_ff=3072, vocab_size=32000,
                        dtype="bfloat16"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ternary_lm")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                       total_steps=args.steps, checkpoint_every=50,
                       checkpoint_dir=args.ckpt)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                       jax.random.PRNGKey(0))))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"steps={args.steps}  QAT=absmean-ternary(STE)")

    state = train_state_init(cfg, tcfg, jax.random.PRNGKey(0))
    stepper = jax.jit(lambda st, b: train_step(st, b, cfg=cfg, tcfg=tcfg))
    fm = FaultManager(args.ckpt, checkpoint_every=tcfg.checkpoint_every)

    def batch_fn(step):
        return jax.tree.map(jnp.asarray, data_lib.synthetic_batch(
            cfg, args.batch, args.seq, step))

    t0 = time.time()

    def on_metrics(step, m):
        if step % 10 == 0:
            print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")

    state = fm.run(state, stepper, batch_fn, args.steps,
                   state_like=state, on_metrics=on_metrics)

    print("converting trained weights -> RSR serve indices (Algorithm 1)...")
    serve_tree = tfm.serve_params(state["params"], cfg)
    idx_bytes = sum(
        l.size * l.dtype.itemsize
        for p, l in jax.tree_util.tree_flatten_with_path(serve_tree)[0]
        if str(getattr(p[-1], "key", "")) == "codes")
    print(f"done: serve index (packed codes) = {idx_bytes/2**20:.1f} MiB "
          f"(vs {n_params * 2 / 2**20:.1f} MiB bf16 dense) — "
          f"ready for repro.serve.engine.Engine")


if __name__ == "__main__":
    main()
