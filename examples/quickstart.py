"""Quickstart: preprocess a ternary weight matrix once, multiply fast forever.

    PYTHONPATH=src python examples/quickstart.py [--n 2048]

Demonstrates the paper's full pipeline on one matrix:
  1. ternary weights  ->  Prop 2.1 binary pair / base-3 direct codes
  2. Algorithm 1      ->  (σ, L) index + packed code array
  3. Algorithm 2/3    ->  v·A via segmented sums (+ RSR++ fold)
  4. equality vs naive matmul, index-vs-dense memory, CPU timing
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (index_nbytes, optimal_k_rsrpp, preprocess,
                        random_ternary, rsr_matmul)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    args = ap.parse_args()
    n = args.n
    key = jax.random.PRNGKey(0)

    print(f"== RSR quickstart (n={n}) ==")
    a = random_ternary(key, (n, n))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n,))

    k = optimal_k_rsrpp(n)
    print(f"optimal k (Eq. 7): {k}")

    t0 = time.perf_counter()
    idx = preprocess(a, k, mode="ternary")              # offline, once
    jax.block_until_ready(jax.tree.leaves(idx))
    print(f"preprocess (Algorithm 1): {time.perf_counter()-t0:.3f}s")

    y_naive = v @ a.astype(jnp.float32)
    for impl in ("segments", "scatter", "onehot"):
        y = rsr_matmul(v, idx, impl=impl, plus_plus=True)
        err = float(jnp.abs(y - y_naive).max())
        print(f"impl={impl:9s} max|err| vs naive = {err:.2e}")

    dense_f32 = n * n * 4
    dense_int8 = n * n
    print(f"memory: dense f32 {dense_f32/2**20:.1f} MiB | "
          f"index (sigma,L) {index_nbytes(idx)/2**20:.1f} MiB "
          f"({dense_f32/index_nbytes(idx):.2f}x) | "
          f"packed codes {index_nbytes(idx,'codes')/2**20:.2f} MiB "
          f"({dense_int8/index_nbytes(idx,'codes'):.2f}x vs int8)")

    # timing (jit-compiled, CPU)
    f_rsr = jax.jit(lambda vv: rsr_matmul(vv, idx, impl="scatter"))
    f_dense = jax.jit(lambda vv: vv @ a.astype(jnp.float32))
    for name, f in (("rsr", f_rsr), ("dense", f_dense)):
        f(v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            f(v).block_until_ready()
        print(f"{name:6s} matvec: {(time.perf_counter()-t0)/10*1e6:.0f} us")


if __name__ == "__main__":
    main()
