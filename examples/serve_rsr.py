"""End-to-end serving driver (the paper's target workload): load/initialize a
ternary model, preprocess to RSR indices, and serve batched generation
requests through the continuous-batching scheduler.

    PYTHONPATH=src python examples/serve_rsr.py --requests 6 --max-new 12

Verifies (as in paper §5.3) that RSR responses are token-identical to the
dense-served model while the weights live as 1.6-bit/weight code arrays.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve.engine import BatchScheduler, Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon3-3b-1.58bit")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    # reduced config: full-size serving needs the TPU pod (see launch/dryrun)
    cfg = get_config(args.arch).reduced()
    print(f"arch={args.arch} (reduced for CPU demo)  "
          f"L={cfg.num_layers} d={cfg.d_model}")

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    t0 = time.time()
    serve_tree = tfm.serve_params(params, cfg)            # Algorithm 1
    print(f"preprocessing (offline, once): {time.time()-t0:.2f}s")

    scfg = ServeConfig(max_seq_len=96, batch_size=args.batch)
    engine = Engine(cfg, serve_tree, scfg)
    sched = BatchScheduler(engine)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(4, 12)).astype(np.int32)
        sched.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on 1 CPU core)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {r.generated}")

    # paper §5.3 equality check vs dense serving
    dense_engine = Engine(cfg, tfm.serve_params(
        params, dataclasses.replace(cfg, rsr_serve=False)), scfg)
    p = jnp.asarray(done[0].prompt)[None, :].repeat(args.batch, 0)
    engine.reset()
    np.testing.assert_array_equal(engine.generate(p, 8),
                                  dense_engine.generate(p, 8))
    print("RSR output == dense output: verified")


if __name__ == "__main__":
    main()
