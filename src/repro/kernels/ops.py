"""Jit'd public wrappers around the Pallas kernels (index-pytree interface).

Handles shape padding to tile multiples (safe: activation rows pad with zeros,
extra column blocks are sliced off the output), index-type dispatch, and the
scale application of quantized linears.  ``interpret=None`` auto-resolves:
Pallas-compiled on a TPU runtime, interpreter (HLO simulation) elsewhere —
no call-site flag flipping.

This module keeps the research-facing interface (full RSR index pytrees, all
three ternary modes).  The serve graph's params-dict hot path lives in
:mod:`repro.kernels.dispatch`, which adds backend fallback, packed-code
streaming, and the fused epilogue on top of the same kernel.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import binlib
from repro.core.preprocess import (BinaryRSRIndex, TernaryDirectIndex,
                                   TernaryRSRIndex)
from repro.kernels.rsr_onehot import rsr_onehot_matmul
from repro.kernels.ternary_dequant import ternary_dequant_matmul

__all__ = ["rsr_matmul_kernel", "ternary_matmul_kernel"]

AnyIndex = Union[BinaryRSRIndex, TernaryRSRIndex, TernaryDirectIndex]


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def rsr_matmul_kernel(v: jax.Array, idx: AnyIndex, *,
                      scale: Optional[jax.Array] = None,
                      fused_ternary: bool = True,
                      tile_b: int = 8, tile_blk: int = 8, tile_n: int = 256,
                      interpret: Optional[bool] = None) -> jax.Array:
    """v (..., n) × indexed matrix -> (..., m) through the Pallas kernel.

    ``scale`` fuses into the kernel epilogue (single-pass modes); the Prop 2.1
    two-pass mode applies it after the pos−neg combine."""
    lead = v.shape[:-1]
    n = v.shape[-1]
    x = v.reshape(-1, n)
    b = x.shape[0]
    x = _pad_to(_pad_to(x, 0, tile_b), 1, tile_n)

    if isinstance(idx, TernaryRSRIndex) and not fused_ternary:
        pos = rsr_matmul_kernel(v, idx.pos, tile_b=tile_b, tile_blk=tile_blk,
                                tile_n=tile_n, interpret=interpret)
        neg = rsr_matmul_kernel(v, idx.neg, tile_b=tile_b, tile_blk=tile_blk,
                                tile_n=tile_n, interpret=interpret)
        out = pos - neg
        return out * scale if scale is not None else out

    if isinstance(idx, TernaryRSRIndex):
        codes, neg_codes = idx.pos.codes, idx.neg.codes
        pattern = binlib.bin_matrix(idx.k)
        k, m = idx.k, idx.m
    elif isinstance(idx, BinaryRSRIndex):
        codes, neg_codes = idx.codes, None
        pattern = binlib.bin_matrix(idx.k)
        k, m = idx.k, idx.m
    elif isinstance(idx, TernaryDirectIndex):
        codes, neg_codes = idx.codes, None
        pattern = binlib.tern_matrix(idx.k)
        k, m = idx.k, idx.m
    else:
        raise TypeError(type(idx))

    codes = _pad_to(_pad_to(codes, 0, tile_blk), 1, tile_n)
    if neg_codes is not None:
        neg_codes = _pad_to(_pad_to(neg_codes, 0, tile_blk), 1, tile_n)

    y = rsr_onehot_matmul(x, codes, pattern, neg_codes, scale=scale,
                          tile_b=tile_b, tile_blk=tile_blk, tile_n=tile_n,
                          interpret=interpret)
    return y[:b, :m].reshape(*lead, m)


def ternary_matmul_kernel(v: jax.Array, packed: jax.Array, m: int, *,
                          scale: Optional[jax.Array] = None,
                          tile_b: int = 8, tile_m: int = 128,
                          tile_n: int = 256,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Dense baseline: v (..., n) × unpack2bit(packed) -> (..., m)."""
    lead = v.shape[:-1]
    n = v.shape[-1]
    x = v.reshape(-1, n)
    b = x.shape[0]
    x = _pad_to(_pad_to(x, 0, tile_b), 1, tile_n)
    packed = _pad_to(_pad_to(packed, 0, tile_n // 4), 1, tile_m)
    y = ternary_dequant_matmul(x, packed, tile_b=tile_b, tile_m=tile_m,
                               tile_n=tile_n, interpret=interpret)
    y = y[:b, :m].reshape(*lead, m)
    return y * scale if scale is not None else y
