"""Pallas TPU kernel: dense 2-bit-packed ternary dequant matmul ("Standard").

The strongest practical dense baseline the paper's technique competes with on
TPU: weights stored 2-bit packed (4 ternary values / byte along the
contraction dim), unpacked to {-1,0,+1} in-register and fed to the MXU.
HBM weight traffic = n·m/4 bytes (vs n·m·0.2 for RSR ternary-direct codes).

y = x @ A,  x (B, n) float, A (n, m) ternary packed as (n/4, m) uint8.

Grid (batch tiles, m tiles, n tiles), accumulation over the innermost n axis
directly into the output block (revisited across n steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rsr_onehot import _CompilerParams

__all__ = ["ternary_dequant_matmul"]


def _kernel(x_ref, packed_ref, out_ref, acc_ref, *, n_steps: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                 # (TB, TN)
    packed = packed_ref[...]                           # (TN//4, TM) uint8
    tn4, tm = packed.shape
    # unpack 4 row values per byte: fields c ∈ {0,1,2} -> {0,+1,-1}
    shifts = (jax.lax.broadcasted_iota(jnp.int32, (1, 4, 1), 1) * 2
              ).astype(jnp.uint8)
    fields = (packed[:, None, :] >> shifts) & jnp.uint8(3)   # (TN/4, 4, TM)
    w = jnp.where(fields == 2, -1.0, fields.astype(jnp.float32))
    w = w.reshape(tn4 * 4, tm)                         # (TN, TM)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(i == n_steps - 1)
    def _write():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "tile_m", "tile_n", "interpret"))
def ternary_dequant_matmul(x: jax.Array, packed: jax.Array, *,
                           tile_b: int = 8, tile_m: int = 128,
                           tile_n: int = 256,
                           interpret: bool = None) -> jax.Array:
    """x (B, n) · unpack(packed) -> (B, m) float32.  packed: (n/4, m) uint8.

    interpret=None auto-resolves: compiled on TPU, interpreter elsewhere."""
    if interpret is None:
        from repro.kernels.rsr_onehot import default_interpret
        interpret = default_interpret()
    b, n = x.shape
    n4, m = packed.shape
    assert n4 * 4 == n, (n4, n)
    assert b % tile_b == 0 and m % tile_m == 0 and n % tile_n == 0
    n_steps = n // tile_n
    grid = (b // tile_b, m // tile_m, n_steps)
    kernel = functools.partial(_kernel, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, tile_n), lambda bi, mi, ni: (bi, ni)),
            pl.BlockSpec((tile_n // 4, tile_m), lambda bi, mi, ni: (ni, mi)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda bi, mi, ni: (bi, mi)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_b, tile_m), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, packed)
