"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ternary import unpack2bit

__all__ = ["rsr_onehot_ref", "ternary_dequant_ref"]


def rsr_onehot_ref(x: jax.Array, codes: jax.Array, pattern: jax.Array,
                   neg_codes: jax.Array | None = None) -> jax.Array:
    """Oracle for rsr_onehot_matmul: explicit one-hot einsum, fp32."""
    p = pattern.shape[0]
    ar = jnp.arange(p, dtype=jnp.int32)
    oh = (codes.astype(jnp.int32)[..., None] == ar).astype(jnp.float32)
    if neg_codes is not None:
        oh = oh - (neg_codes.astype(jnp.int32)[..., None] == ar).astype(
            jnp.float32)
    u = jnp.einsum("bn,cnp->bcp", x.astype(jnp.float32), oh)
    y = jnp.einsum("bcp,pk->bck", u, pattern.astype(jnp.float32))
    return y.reshape(x.shape[0], -1)


def ternary_dequant_ref(x: jax.Array, packed: jax.Array) -> jax.Array:
    """Oracle for ternary_dequant_matmul: unpack then dense fp32 matmul."""
    n = packed.shape[0] * 4
    w = unpack2bit(packed, n).astype(jnp.float32)
    return x.astype(jnp.float32) @ w
