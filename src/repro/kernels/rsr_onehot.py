"""Pallas TPU kernel: RSR one-hot matmul (the paper's technique, TPU-native).

Computes ``y = x @ W`` for a binary/ternary W represented **only by its RSR
code arrays** (DESIGN.md §2).  Per k-column block b:

    u_b = x · OneHot(codes[b])        (MXU matmul; one-hot built in-register
                                       from the streamed k-bit codes)
    y_b = u_b · pattern               (pattern = Bin_[k] or Tern_[k]; tiny)

HBM traffic for the weight side is the code array alone — the TPU
materialization of the paper's "index instead of matrix" insight.  The same
kernel body serves three modes (chosen by what the wrapper feeds it):

  * binary RSR        : one code array, pattern = Bin_[k]   (P = 2^k)
  * ternary fused     : two code arrays (Prop 2.1), signed one-hot
                        OH(pos) − OH(neg), pattern = Bin_[k]
  * ternary direct    : one base-3 code array, pattern = Tern_[k] (P = 3^k)
                        — beyond-paper, 1.6 bits/weight traffic.

Packed-code streaming
---------------------
With ``packed=True`` the codes operand is the **word-packed** form produced by
:func:`repro.core.preprocess.pack_code_words`: 4 uint8 codes (or 2 uint16
codes) per uint32 word along the contraction (n) axis.  The kernel unpacks the
words in-register with shifts/masks, so the HBM weight-side stream is exactly
``32 / (codes_per_word · k)`` bits per weight — 8/k = 1.6 bits/weight at the
serve default k=5 — instead of the ≥8 bits/weight an unpacked uint8 (padded to
int8 sublane tiling, or widened to i32 lanes by Mosaic) code array costs.

Fused epilogue
--------------
``scale`` (the absmean dequant γ) and ``bias`` fold into the final-step
projection, so a quantized serve linear is ONE kernel launch: the projection
through ``pattern`` produces the (TB, TBLK·k) output tile already scaled and
biased, and the only work left outside is the static n_out column slice (the
output shape of a pallas_call is fixed per-grid-cell, so the slice cannot move
inside; it is a zero-copy XLA slice).

Grid: (batch tiles, block tiles, n tiles); the contraction (n) axis is the
innermost, accumulated in a VMEM scratch ``u`` of shape (TBLK, TB, P) and
projected through ``pattern`` on the final n step.

Tiling notes (v5e): TN multiple of 128 feeds the MXU contraction dim aligned;
P ≤ 256 keeps each one-hot (TN, P) tile ≤ 128 KB fp32 in VMEM; the unrolled
python loop over TBLK blocks keeps per-iteration VMEM at one one-hot tile.
Tile selection is owned by the autotune table in
:mod:`repro.kernels.dispatch`, not hardcoded call sites.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rsr_onehot_matmul", "default_interpret"]

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x releases.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def default_interpret() -> bool:
    """Pallas-compiled on TPU; interpret (HLO simulation) everywhere else."""
    return jax.default_backend() != "tpu"


def _unpack_words(words: jax.Array, code_bits: int) -> jax.Array:
    """(TBLK, TNW) uint32 words -> (TBLK, TNW * codes_per_word) int32 codes.

    Little-endian within the word, matching pack_code_words: code j of a word
    lives at bits [j*code_bits, (j+1)*code_bits).
    """
    per = 32 // code_bits
    mask = jnp.uint32((1 << code_bits) - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * code_bits)[None, None, :]
    codes = (words.astype(jnp.uint32)[:, :, None] >> shifts) & mask
    return codes.reshape(words.shape[0], -1).astype(jnp.int32)


def _kernel(x_ref, codes_ref, neg_ref, pat_ref, scale_ref, bias_ref, out_ref,
            u_ref, *, n_steps: int, signed: bool, code_bits: int,
            packed: bool, fuse_scale: bool, fuse_bias: bool):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...].astype(jnp.float32)              # (TB, TN)
    if packed:                                      # in-register unpack
        codes = _unpack_words(codes_ref[...], code_bits)        # (TBLK, TN)
        neg = _unpack_words(neg_ref[...], code_bits) if signed else None
    else:
        codes = codes_ref[...].astype(jnp.int32)    # (TBLK, TN)
        neg = neg_ref[...].astype(jnp.int32) if signed else None
    tblk, tn = codes.shape
    p = u_ref.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, p), 1)
    for b in range(tblk):                           # static unroll
        oh = (codes[b][:, None] == iota).astype(jnp.float32)
        if signed:
            oh = oh - (neg[b][:, None] == iota).astype(jnp.float32)
        u_ref[b] += jnp.dot(x, oh, preferred_element_type=jnp.float32)

    @pl.when(i == n_steps - 1)
    def _project():
        pat = pat_ref[...].astype(jnp.float32)      # (P, k)
        u = u_ref[...]                              # (TBLK, TB, P)
        y = jax.lax.dot_general(
            u, pat, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)     # (TBLK, TB, k)
        tb = y.shape[1]
        y = y.transpose(1, 0, 2).reshape(tb, -1)    # (TB, TBLK*k)
        if fuse_scale:                              # epilogue: γ · y + b
            y = y * scale_ref[0, 0]
        if fuse_bias:
            y = y + bias_ref[...]
        out_ref[...] = y.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile_b", "tile_blk", "tile_n", "interpret",
                     "code_bits", "packed", "out_dtype"))
def rsr_onehot_matmul(x: jax.Array,
                      codes: jax.Array,
                      pattern: jax.Array,
                      neg_codes: Optional[jax.Array] = None,
                      *,
                      scale: Optional[jax.Array] = None,
                      bias: Optional[jax.Array] = None,
                      tile_b: int = 8,
                      tile_blk: int = 8,
                      tile_n: int = 256,
                      interpret: Optional[bool] = None,
                      code_bits: int = 8,
                      packed: bool = False,
                      out_dtype=jnp.float32) -> jax.Array:
    """y[..B, nb*k] = x[..B, n] · W  with W given as RSR codes.

    x        : (B, n) activations (any float dtype)
    codes    : (nb, n) integer code array (pattern value per row per block),
               or with ``packed=True`` the (nb, n / (32 // code_bits)) uint32
               word-packed form from ``pack_code_words``
    pattern  : (P, k) Bin_[k] / Tern_[k] enumeration matrix
    neg_codes: optional second code array -> signed one-hot (ternary fused)
    scale    : optional scalar γ fused into the epilogue
    bias     : optional (nb*k,) fp32 bias (zero-padded past n_out) fused into
               the epilogue
    interpret: None -> ``default_interpret()`` (compiled iff on TPU)

    B, nb, n must be multiples of the respective tiles (wrappers in ops.py /
    dispatch.py pad).  Returns (B, nb*k) ``out_dtype``.
    """
    if interpret is None:
        interpret = default_interpret()
    b, n_x = x.shape
    per_word = (32 // code_bits) if packed else 1
    nb, nw = codes.shape
    assert nw * per_word == n_x, (nw, per_word, n_x)
    p, k = pattern.shape
    tile_nw = tile_n // per_word
    assert b % tile_b == 0 and nb % tile_blk == 0 and n_x % tile_n == 0 \
        and tile_n % per_word == 0, (b, nb, n_x, tile_b, tile_blk, tile_n)
    n_steps = n_x // tile_n
    signed = neg_codes is not None
    if not signed:                       # dummy ref, never read
        neg_codes = codes
    fuse_scale = scale is not None
    if not fuse_scale:
        scale = jnp.ones((), jnp.float32)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    fuse_bias = bias is not None
    if not fuse_bias:                    # dummy ref, never read
        bias = jnp.zeros((1, tile_blk * k), jnp.float32)
    else:
        bias = jnp.asarray(bias, jnp.float32).reshape(1, nb * k)

    grid = (b // tile_b, nb // tile_blk, n_steps)
    kernel = functools.partial(_kernel, n_steps=n_steps, signed=signed,
                               code_bits=code_bits, packed=packed,
                               fuse_scale=fuse_scale, fuse_bias=fuse_bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, tile_n), lambda bi, ji, ii: (bi, ii)),
            pl.BlockSpec((tile_blk, tile_nw), lambda bi, ji, ii: (ji, ii)),
            pl.BlockSpec((tile_blk, tile_nw), lambda bi, ji, ii: (ji, ii)),
            pl.BlockSpec((p, k), lambda bi, ji, ii: (0, 0)),
            pl.BlockSpec((1, 1), lambda bi, ji, ii: (0, 0)),
            pl.BlockSpec((1, tile_blk * k),
                         lambda bi, ji, ii: (0, ji) if fuse_bias else (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_blk * k),
                               lambda bi, ji, ii: (bi, ji)),
        out_shape=jax.ShapeDtypeStruct((b, nb * k), out_dtype),
        scratch_shapes=[pltpu.VMEM((tile_blk, tile_b, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, codes, neg_codes, pattern, scale, bias)
