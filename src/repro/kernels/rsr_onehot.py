"""Pallas TPU kernel: RSR one-hot matmul (the paper's technique, TPU-native).

Computes ``y = x @ W`` for a binary/ternary W represented **only by its RSR
code arrays** (DESIGN.md §2).  Per k-column block b:

    u_b = x · OneHot(codes[b])        (MXU matmul; one-hot built in-register
                                       from the streamed k-bit codes)
    y_b = u_b · pattern               (pattern = Bin_[k] or Tern_[k]; tiny)

HBM traffic for the weight side is the code array alone — the TPU
materialization of the paper's "index instead of matrix" insight.  The same
kernel body serves three modes (chosen by what the wrapper feeds it):

  * binary RSR        : one code array, pattern = Bin_[k]   (P = 2^k)
  * ternary fused     : two code arrays (Prop 2.1), signed one-hot
                        OH(pos) − OH(neg), pattern = Bin_[k]
  * ternary direct    : one base-3 code array, pattern = Tern_[k] (P = 3^k)
                        — beyond-paper, 1.6 bits/weight traffic.

Grid: (batch tiles, block tiles, n tiles); the contraction (n) axis is the
innermost, accumulated in a VMEM scratch ``u`` of shape (TBLK, TB, P) and
projected through ``pattern`` on the final n step.

Tiling notes (v5e): TN multiple of 128 feeds the MXU contraction dim aligned;
P ≤ 256 keeps each one-hot (TN, P) tile ≤ 128 KB fp32 in VMEM; the unrolled
python loop over TBLK blocks keeps per-iteration VMEM at one one-hot tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rsr_onehot_matmul"]


def _kernel(x_ref, codes_ref, neg_ref, pat_ref, out_ref, u_ref, *,
            n_steps: int, signed: bool):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...].astype(jnp.float32)              # (TB, TN)
    codes = codes_ref[...].astype(jnp.int32)        # (TBLK, TN)
    neg = neg_ref[...].astype(jnp.int32) if signed else None
    tblk, tn = codes.shape
    p = u_ref.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, p), 1)
    for b in range(tblk):                           # static unroll
        oh = (codes[b][:, None] == iota).astype(jnp.float32)
        if signed:
            oh = oh - (neg[b][:, None] == iota).astype(jnp.float32)
        u_ref[b] += jnp.dot(x, oh, preferred_element_type=jnp.float32)

    @pl.when(i == n_steps - 1)
    def _project():
        pat = pat_ref[...].astype(jnp.float32)      # (P, k)
        u = u_ref[...]                              # (TBLK, TB, P)
        y = jax.lax.dot_general(
            u, pat, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)     # (TBLK, TB, k)
        tb = y.shape[1]
        out_ref[...] = y.transpose(1, 0, 2).reshape(tb, -1).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile_b", "tile_blk", "tile_n", "interpret"))
def rsr_onehot_matmul(x: jax.Array,
                      codes: jax.Array,
                      pattern: jax.Array,
                      neg_codes: Optional[jax.Array] = None,
                      *,
                      tile_b: int = 8,
                      tile_blk: int = 8,
                      tile_n: int = 256,
                      interpret: bool = True) -> jax.Array:
    """y[..B, nb*k] = x[..B, n] · W  with W given as RSR codes.

    x        : (B, n) activations (any float dtype)
    codes    : (nb, n) integer code array (pattern value per row per block)
    pattern  : (P, k) Bin_[k] / Tern_[k] enumeration matrix
    neg_codes: optional second code array -> signed one-hot (ternary fused)

    B, nb, n must be multiples of the respective tiles (wrapper in ops.py
    pads).  Returns (B, nb*k) float32.
    """
    b, n = x.shape
    nb, n2 = codes.shape
    assert n == n2, (n, n2)
    p, k = pattern.shape
    assert b % tile_b == 0 and nb % tile_blk == 0 and n % tile_n == 0, \
        (b, nb, n, tile_b, tile_blk, tile_n)
    n_steps = n // tile_n
    signed = neg_codes is not None
    if not signed:                       # dummy ref, never read
        neg_codes = codes

    grid = (b // tile_b, nb // tile_blk, n_steps)
    kernel = functools.partial(_kernel, n_steps=n_steps, signed=signed)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, tile_n), lambda bi, ji, ii: (bi, ii)),
            pl.BlockSpec((tile_blk, tile_n), lambda bi, ji, ii: (ji, ii)),
            pl.BlockSpec((tile_blk, tile_n), lambda bi, ji, ii: (ji, ii)),
            pl.BlockSpec((p, k), lambda bi, ji, ii: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_blk * k),
                               lambda bi, ji, ii: (bi, ji)),
        out_shape=jax.ShapeDtypeStruct((b, nb * k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_blk, tile_b, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, codes, neg_codes, pattern)
