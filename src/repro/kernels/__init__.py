"""Pallas TPU kernels for the perf-critical compute: RSR one-hot matmul (the
paper's technique) and the dense 2-bit dequant baseline.  Validated against
ref.py oracles in interpret mode; TPU is the target hardware."""
from repro.kernels.ops import rsr_matmul_kernel, ternary_matmul_kernel
from repro.kernels.rsr_onehot import rsr_onehot_matmul
from repro.kernels.ternary_dequant import ternary_dequant_matmul
