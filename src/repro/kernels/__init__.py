"""Pallas TPU kernels for the perf-critical compute: RSR one-hot matmul (the
paper's technique) and the dense 2-bit dequant baseline.  Validated against
ref.py oracles in interpret mode; TPU is the target hardware.

Layering: ``rsr_onehot`` is the raw kernel (strict tiles, packed-code
streaming, fused epilogue); ``ops`` wraps it with padding + index-pytree
dispatch for research use; ``dispatch`` is the serve hot path — backend
selection (pallas / pallas_interpret / scatter), the tile autotune table,
and the params-dict contract the model serve graph speaks.
``paged_attention`` is the KV side of the serve hot path: decode/append
attention computed in place over the block-paged KV pools through the
per-slot block tables (no dense gather), behind the ``REPRO_PAGED_ATTN``
switch."""
from repro.kernels.dispatch import (rsr_serve_linear, rsr_serve_matmul,
                                    select_backend, select_tiles)
from repro.kernels.ops import rsr_matmul_kernel, ternary_matmul_kernel
from repro.kernels.paged_attention import (paged_gqa_attend, paged_mla_attend,
                                           select_paged_backend)
from repro.kernels.rsr_onehot import rsr_onehot_matmul
from repro.kernels.ternary_dequant import ternary_dequant_matmul
