"""Pallas TPU kernels: paged attention over the block-paged KV cache.

The JAX paged path (``repro.models.attention._gather_blocks``) is bitwise-
clean but materializes a dense ``(B, S, ·)`` KV view per layer per step:
every decode step reads the slot's pool blocks, WRITES an S-row dense copy,
and the score einsums read that copy back — ~3× the KV bytes of the
sequence, on exactly the memory-bound decode path the RSR kernel exists to
accelerate.  The kernels here score queries against the pool blocks **in
place**: the per-slot block table is a scalar-prefetch operand whose values
drive the KV BlockSpec index maps (the vLLM-TPU idiom), so each physical
block is DMA'd HBM→VMEM exactly once per step and no dense view ever
exists.  Softmax is accumulated online across blocks (flash-style running
max/sum in VMEM scratch), so arbitrarily long tables stream through a
fixed-size working set.

Kernel family (one grid shape, ``(B, C-tiles, blocks)``, innermost axis
sequential):

* :func:`paged_gqa_attend` — GQA/MQA over ``(NB+1, KVH, bs, hd)`` pools.
  ``ring_slots=0`` is the full-attention causal form; ``ring_slots=W``
  applies the sliding-window ring-buffer age mask instead (the table's
  ring region, same slot arithmetic as the dense scan step).
* :func:`paged_mla_attend` — MLA absorbed-decode over the latent pools
  ``(NB+1, bs, r)`` / ``(NB+1, bs, dr)``: scores are ``q_lat·c + q_pe·pe``
  and the value side is the latent ``c`` itself (W_UV is applied by the
  caller, outside the kernel).

C == 1 is the decode step; C > 1 is the chunked append/prefill form (the
same kernel, query-tiled).  Both assume the chunk's K/V have already been
scattered into the pool through the table (an O(C) write the caller owns);
the kernel replaces only the O(S) gather-then-score.

Numerics vs the gather path: identical masking (same NEG_INF, probabilities
cast to the cache dtype before the PV product, matching the dense einsums)
but the softmax is accumulated per block instead of in one shot, so results
agree to float-associativity (~1e-6 f32), not bitwise.  Greedy decodes are
token-identical on the serve configs (asserted in tests/test_paged_attn.py);
the gather path remains the bitwise parity reference behind the
``REPRO_PAGED_ATTN`` switch.

Backend selection mirrors the RSR dispatch contract
(:func:`repro.kernels.dispatch.select_backend`): explicit argument >
``REPRO_PAGED_ATTN`` env var > ``ServeConfig.paged_attn`` > default
(``kernel``).  ``gather`` restores the PR-3 dense-gather path — the right
tool when debugging paged-cache corruption (it is bitwise-equal to the
dense layout, so a divergence under ``gather`` is a table/allocator bug,
while a divergence only under ``kernel`` is a kernel bug).

Tile regime: the query-tile table below mirrors ``AUTOTUNE_TABLE`` in
dispatch.py — decode (C == 1) runs untiled, prefill chunks tile C to bound
the (tile_c, H, ·) working set; measured winners land in
``TUNED_ATTN_TILES`` (per-(regime, C-bucket)) via :func:`autotune_paged_attn`
and persist through the same autotune_cache.json that stores the RSR tiles.
"""
from __future__ import annotations

import functools
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rsr_onehot import default_interpret

__all__ = ["PAGED_ATTN_BACKENDS", "select_paged_backend", "paged_gqa_attend",
           "paged_mla_attend", "PAGED_ATTN_TILES", "TUNED_ATTN_TILES",
           "select_attn_tiles", "autotune_paged_attn"]

NEG_INF = -1e30                       # matches repro.models.attention.NEG_INF

PAGED_ATTN_BACKENDS = ("kernel", "gather")

_ENV_VAR = "REPRO_PAGED_ATTN"


def select_paged_backend(requested: Optional[str] = None,
                         cfg_default: Optional[str] = None) -> str:
    """Resolve the paged-attention backend: explicit arg > $REPRO_PAGED_ATTN
    > ``ServeConfig.paged_attn`` (``cfg_default``) > ``kernel``.  Same
    resolution contract as the RSR ``select_backend``; the env var is the
    operator's override (read at trace time — set it before constructing
    the Engine whose jitted step should use it)."""
    for cand in (requested, os.environ.get(_ENV_VAR), cfg_default):
        if cand and cand != "auto":
            if cand not in PAGED_ATTN_BACKENDS:
                raise ValueError(
                    f"paged-attn backend {cand!r} not in "
                    f"{PAGED_ATTN_BACKENDS}")
            return cand
    return "kernel"


# ---------------------------------------------------------------------------
# Query-tile regime table (the attention analogue of dispatch.AUTOTUNE_TABLE)
# ---------------------------------------------------------------------------

# rows: (regime, max C, tile_c).  Decode (C == 1) is untiled; small append
# chunks run whole; prefill chunks tile the query axis so the per-grid-step
# working set (tile_c · H · hd q/out tiles + scratch) stays VMEM-resident
# while the KV blocks stream through.
PAGED_ATTN_TILES = (
    ("decode", 1, 1),
    ("small", 8, 8),
    ("prefill", None, 32),
)

# Measured per-C-bucket overrides, keyed (regime, c_bucket); populated by
# autotune_paged_attn() and persisted alongside the RSR tiles in
# autotune_cache.json (see dispatch.save_autotune_cache / load_autotune_cache).
TUNED_ATTN_TILES: dict[tuple[str, int], int] = {}


def _bucket(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def _attn_regime(c: int) -> str:
    for name, max_c, _ in PAGED_ATTN_TILES:
        if max_c is None or c <= max_c:
            return name
    return PAGED_ATTN_TILES[-1][0]


def select_attn_tiles(c: int) -> int:
    """Query tile (tile_c) for a C-token append step.  Measured entries
    (TUNED_ATTN_TILES) outrank the static regime row; either is clamped to
    the problem (a tile never exceeds C)."""
    tuned = TUNED_ATTN_TILES.get((_attn_regime(c), _bucket(c)))
    if tuned is not None:
        tile_c = tuned
    else:
        for _, max_c, tile_c in PAGED_ATTN_TILES:
            if max_c is None or c <= max_c:
                break
    return max(1, min(tile_c, c))


# ---------------------------------------------------------------------------
# GQA / sliding-window-ring kernel
# ---------------------------------------------------------------------------

def _gqa_paged_kernel(tbl_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, groups: int, n_blocks: int,
                      ring_slots: int, p_dtype):
    """One (slot b, query tile, logical block j) grid step.

    q_ref (1, TC, H, hd) pre-scaled queries; k/v_ref (1, KVH, bs, hd) the
    pool block addressed through the table (scalar-prefetch index map);
    pos_ref (1, TC) absolute query positions.  Scratch m/l (KVH, TC, G),
    acc (KVH, TC, G, hd) carry the online softmax across the innermost
    (sequential) block axis; the output tile is written on the last block.
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (TC, H, hd)
    tc, _, hd = q.shape
    kvh, bs = k_ref.shape[1], k_ref.shape[2]
    qp = pos_ref[...].reshape(tc, 1)                  # (TC, 1) query positions
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (tc, bs), 1)
    if ring_slots:
        # ring-buffer age mask — identical formula to the dense scan step
        # (attention.gqa_apply window branch): kpos is the RING SLOT index
        age = jnp.mod(qp - kpos, ring_slots)
        valid = age < jnp.minimum(qp + 1, ring_slots)
        valid = valid & ((qp - age) >= 0)
    else:
        valid = kpos <= qp                            # causal

    for h in range(kvh):                              # static unroll (small)
        qh = q[:, h * groups:(h + 1) * groups, :].reshape(tc * groups, hd)
        kh = k_ref[0, h].astype(jnp.float32)          # (bs, hd)
        vh = v_ref[0, h].astype(jnp.float32)
        s = jax.lax.dot_general(qh, kh, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s.reshape(tc, groups, bs)
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_ref[h], s.max(-1))      # (TC, G)
        alpha = jnp.exp(m_ref[h] - m_new)
        # exp(NEG_INF - NEG_INF) == 1 when a whole block is masked before
        # any valid key arrives — zero masked probabilities explicitly
        p = jnp.where(valid[:, None, :], jnp.exp(s - m_new[..., None]), 0.0)
        l_ref[h] = l_ref[h] * alpha + p.sum(-1)
        # mirror the gather path's pr.astype(cache dtype) before PV
        pc = p.reshape(tc * groups, bs).astype(p_dtype).astype(jnp.float32)
        pv = jax.lax.dot_general(pc, vh, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[h] = acc_ref[h] * alpha[..., None] + pv.reshape(tc, groups,
                                                                hd)
        m_ref[h] = m_new

    @pl.when(j == n_blocks - 1)
    def _project():
        l = jnp.maximum(l_ref[...], 1e-30)            # (KVH, TC, G)
        o = acc_ref[...] / l[..., None]               # (KVH, TC, G, hd)
        tc_, hd_ = o.shape[1], o.shape[3]
        o_ref[0] = jnp.moveaxis(o, 0, 1).reshape(
            tc_, -1, hd_).astype(o_ref.dtype)         # (TC, H, hd)


def paged_gqa_attend(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     table: jax.Array, positions: jax.Array, *,
                     ring_slots: int = 0, tile_c: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jax.Array:
    """In-place paged attention over GQA pools -> (B, C, H, hd) float32.

    q         : (B, C, H, hd) queries, already scaled (q / sqrt(hd)) and in
                the cache dtype — mirrors the gather path's score input.
    k/v_pool  : (NB+1, KVH, bs, hd) global block pools (+1 trash block).
    table     : (B, MB) int32 physical block ids (the full-attention or
                ring region of the slot table).
    positions : (B, C) int32 absolute query positions (the chunk's K/V must
                already be written at these positions through the table).
    ring_slots: 0 -> causal full attention over logical blocks; W > 0 ->
                sliding-window ring-buffer masking (table is the ring
                region, MB·bs == W).
    """
    b, c, h, hd = q.shape
    mb = table.shape[1]
    assert mb > 0, "paged attention over an empty block table"
    kvh, bs = k_pool.shape[1], k_pool.shape[2]
    groups = h // kvh
    if interpret is None:
        interpret = default_interpret()
    tc = tile_c or select_attn_tiles(c)
    tc = max(1, min(tc, c))
    nc = -(-c // tc)
    pad = nc * tc - c
    if pad:                                 # padded queries are sliced away;
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad)), mode="edge")
    positions = positions.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nc, mb),
        in_specs=[
            pl.BlockSpec((1, tc, h, hd), lambda bi, ci, j, tbl: (bi, ci, 0,
                                                                 0)),
            pl.BlockSpec((1, kvh, bs, hd),
                         lambda bi, ci, j, tbl: (tbl[bi, j], 0, 0, 0)),
            pl.BlockSpec((1, kvh, bs, hd),
                         lambda bi, ci, j, tbl: (tbl[bi, j], 0, 0, 0)),
            pl.BlockSpec((1, tc), lambda bi, ci, j, tbl: (bi, ci)),
        ],
        out_specs=pl.BlockSpec((1, tc, h, hd),
                               lambda bi, ci, j, tbl: (bi, ci, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvh, tc, groups), jnp.float32),
            pltpu.VMEM((kvh, tc, groups), jnp.float32),
            pltpu.VMEM((kvh, tc, groups, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_gqa_paged_kernel, groups=groups,
                               n_blocks=mb, ring_slots=ring_slots,
                               p_dtype=k_pool.dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nc * tc, h, hd), jnp.float32),
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(table.astype(jnp.int32), q, k_pool, v_pool, positions)
    return out[:, :c]


# ---------------------------------------------------------------------------
# MLA (latent-cache, absorbed decode) kernel
# ---------------------------------------------------------------------------

def _mla_paged_kernel(tbl_ref, ql_ref, qp_ref, c_ref, pe_ref, pos_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, n_blocks: int, scale: float,
                      p_dtype):
    """MLA step: scores q_lat·c + q_pe·pe (scaled AFTER the sum, like the
    absorbed dense path), value side is the latent c itself."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ql = ql_ref[0].astype(jnp.float32)                # (TC, H, r)
    qpe = qp_ref[0].astype(jnp.float32)               # (TC, H, dr)
    tc, h, r = ql.shape
    cb = c_ref[0].astype(jnp.float32)                 # (bs, r)
    peb = pe_ref[0].astype(jnp.float32)               # (bs, dr)
    bs = cb.shape[0]
    qp = pos_ref[...].reshape(tc, 1)
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (tc, bs), 1)
    valid = kpos <= qp

    s = jax.lax.dot_general(ql.reshape(tc * h, r), cb,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s + jax.lax.dot_general(qpe.reshape(tc * h, -1), peb,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    s = s.reshape(tc, h, bs) * scale
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m_new = jnp.maximum(m_ref[...], s.max(-1))        # (TC, H)
    alpha = jnp.exp(m_ref[...] - m_new)
    p = jnp.where(valid[:, None, :], jnp.exp(s - m_new[..., None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    pc = p.reshape(tc * h, bs).astype(p_dtype).astype(jnp.float32)
    pv = jax.lax.dot_general(pc, cb, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv.reshape(tc, h, r)
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _project():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def paged_mla_attend(q_lat: jax.Array, q_pe: jax.Array, c_pool: jax.Array,
                     pe_pool: jax.Array, table: jax.Array,
                     positions: jax.Array, *, scale: float,
                     tile_c: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jax.Array:
    """In-place paged MLA attention -> o_lat (B, C, H, r) float32.

    q_lat (B, C, H, r) absorbed queries and q_pe (B, C, H, dr) rope
    queries, both in the cache dtype; c_pool (NB+1, bs, r) latent and
    pe_pool (NB+1, bs, dr) rope-key pools; table (B, MB); positions (B, C).
    The caller applies W_UV to the returned latent output.
    """
    b, c, h, r = q_lat.shape
    dr = q_pe.shape[-1]
    mb = table.shape[1]
    assert mb > 0, "paged attention over an empty block table"
    bs = c_pool.shape[1]
    if interpret is None:
        interpret = default_interpret()
    tc = tile_c or select_attn_tiles(c)
    tc = max(1, min(tc, c))
    nc = -(-c // tc)
    pad = nc * tc - c
    if pad:
        q_lat = jnp.pad(q_lat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pe = jnp.pad(q_pe, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad)), mode="edge")
    positions = positions.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nc, mb),
        in_specs=[
            pl.BlockSpec((1, tc, h, r), lambda bi, ci, j, tbl: (bi, ci, 0,
                                                                0)),
            pl.BlockSpec((1, tc, h, dr), lambda bi, ci, j, tbl: (bi, ci, 0,
                                                                 0)),
            pl.BlockSpec((1, bs, r), lambda bi, ci, j, tbl: (tbl[bi, j], 0,
                                                             0)),
            pl.BlockSpec((1, bs, dr), lambda bi, ci, j, tbl: (tbl[bi, j], 0,
                                                              0)),
            pl.BlockSpec((1, tc), lambda bi, ci, j, tbl: (bi, ci)),
        ],
        out_specs=pl.BlockSpec((1, tc, h, r),
                               lambda bi, ci, j, tbl: (bi, ci, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tc, h), jnp.float32),
            pltpu.VMEM((tc, h), jnp.float32),
            pltpu.VMEM((tc, h, r), jnp.float32),
        ],
    )
    kernel = functools.partial(_mla_paged_kernel, n_blocks=mb, scale=scale,
                               p_dtype=c_pool.dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nc * tc, h, r), jnp.float32),
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(table.astype(jnp.int32), q_lat, q_pe, c_pool, pe_pool, positions)
    return out[:, :c]


def _compiler_params():
    cp = getattr(pltpu, "CompilerParams",
                 getattr(pltpu, "TPUCompilerParams", None))
    return cp(dimension_semantics=("parallel", "parallel", "arbitrary"))


# ---------------------------------------------------------------------------
# Offline autotune (query-tile winners -> TUNED_ATTN_TILES -> cache file)
# ---------------------------------------------------------------------------

def autotune_paged_attn(c: int, *, heads: int = 8, kv_heads: int = 1,
                        head_dim: int = 128, block_size: int = 16,
                        num_blocks: int = 16,
                        candidates=(1, 8, 16, 32, 64),
                        reps: int = 3, write=None) -> dict:
    """Measure query-tile candidates for a C-token append step at the given
    cache geometry; records the winner in TUNED_ATTN_TILES under its
    (regime, C-bucket) key and (with ``write``) persists it through the
    shared autotune cache (dispatch.save_autotune_cache)."""
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, c, heads, head_dim))
    k_pool = jax.random.normal(kk, (num_blocks + 1, kv_heads, block_size,
                                    head_dim))
    v_pool = jax.random.normal(kv, (num_blocks + 1, kv_heads, block_size,
                                    head_dim))
    table = jnp.arange(num_blocks, dtype=jnp.int32)[None, :]
    positions = jnp.arange(c, dtype=jnp.int32)[None, :]
    rows = []
    seen = set()
    for cand in candidates:
        tc = max(1, min(cand, c))
        if tc in seen:
            continue
        seen.add(tc)
        fn = jax.jit(functools.partial(paged_gqa_attend, tile_c=tc))
        fn(q, k_pool, v_pool, table, positions).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(q, k_pool, v_pool, table, positions).block_until_ready()
        rows.append((tc, (time.perf_counter() - t0) / reps * 1e6))
    rows.sort(key=lambda r: r[1])
    key_t = (_attn_regime(c), _bucket(c))
    TUNED_ATTN_TILES[key_t] = rows[0][0]
    out = {"tile_c": rows[0][0], "us": rows[0][1], "rows": rows,
           "key": key_t}
    if write:
        from repro.kernels.dispatch import save_autotune_cache
        out["cache_path"] = save_autotune_cache(
            None if write is True else write)
    return out
