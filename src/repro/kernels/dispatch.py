"""Backend dispatch for quantized serve linears — the RSR hot path.

Every quantized linear in the serve graph (`repro.models.modules
.rsr_linear_apply`, the MoE expert banks, and the Engine's decode step) routes
through :func:`rsr_serve_linear` here.  The dispatcher owns three decisions
the call sites used to hardcode:

1. **Backend selection** (:func:`select_backend`):

   * ``pallas``           — compiled Pallas kernel; TPU runtime.
   * ``pallas_interpret`` — the same kernel through the Pallas interpreter
     (lowers to plain HLO); exact same dataflow, runs anywhere.  This is the
     CPU default so every test and container run exercises the production
     kernel path.
   * ``scatter``          — pure-JAX vmapped bucket scatter-add fallback
     (the strongest XLA-only contraction per EXPERIMENTS.md SS Perf); used
     when the Pallas interpreter is unavailable or explicitly requested.

   Resolution order: explicit argument > ``REPRO_RSR_BACKEND`` env var >
   ``cfg.rsr_backend`` > auto (``pallas`` iff ``jax.default_backend() ==
   "tpu"``, else ``pallas_interpret``).

2. **Tile selection** (:func:`select_tiles`): a small static autotune table
   keyed by the flattened batch-row regime.  The decode regime (B ≤ 8, the
   LLM serving hot path and the paper's 5.24× vector-matrix target) takes the
   minimum fp32 batch tile and a deep contraction tile so the code stream —
   not the activation stream — dominates HBM traffic; prefill regimes widen
   the batch tile to amortize the one-hot build across MXU rows (the
   chunked-prefill engine path flattens B·chunk rows, which is what lands
   here).  ``autotune()`` measures candidates per shape and records winners
   in a per-(n, nb)-bucketed overlay (``TUNED_TILES``) that outranks the
   static rows; ``autotune(..., write=...)`` / the ``python -m
   repro.kernels.dispatch --write`` CLI persist it to autotune_cache.json,
   reloaded over the table at import — so a TPU session's measurements
   survive the session.

3. **Epilogue fusion**: scale (absmean γ), bias, and output dtype are handed
   to the kernel's final-step projection, so a serve linear is one kernel
   launch plus a zero-copy n_out column slice.  The scatter fallback applies
   the same epilogue in jnp.

Serve params contract (produced by ``serve_linear_params``):

    {"codes":  (nb, n) uint8/uint16      — per-row base-3 pattern values,
     "packed": (nb, ceil(n/per)) uint32  — pack_code_words(codes); the ONLY
                                           weight-side array the Pallas path
                                           streams (≤ 8·itemsize/k ≈ 1.6
                                           bits/weight at k=5),
     "scale":  ()                        — absmean dequant γ,
     "n_out":  (n_out, 0) marker        — static true output width (shape-
                                           encoded: zero-size, jit/vmap-safe),
     "b":      (n_out,) optional        — bias}
"""
from __future__ import annotations

import functools
import json
import logging
import os
import time
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import binlib
from repro.kernels.ops import _pad_to
from repro.kernels.rsr_onehot import default_interpret, rsr_onehot_matmul
# stdlib-only module: safe to import from kernel code (no serve cycle);
# record_dispatch is a host-side count at trace time, not a traced op
from repro.serve import telemetry

__all__ = ["BACKENDS", "select_backend", "select_tiles", "rsr_serve_linear",
           "rsr_serve_matmul", "autotune", "AUTOTUNE_TABLE", "TUNED_TILES",
           "save_autotune_cache", "load_autotune_cache",
           "AutotuneCacheError", "validate_autotune_payload"]

BACKENDS = ("pallas", "pallas_interpret", "scatter")

_ENV_VAR = "REPRO_RSR_BACKEND"


def select_backend(requested: Optional[str] = None,
                   cfg_default: Optional[str] = None) -> str:
    """Resolve a backend name: explicit arg > $REPRO_RSR_BACKEND >
    cfg.rsr_backend (``cfg_default``) > hardware auto — the env var is the
    operator's override of a model config's pinned backend."""
    for cand in (requested, os.environ.get(_ENV_VAR), cfg_default):
        if cand and cand != "auto":
            if cand not in BACKENDS:
                raise ValueError(f"backend {cand!r} not in {BACKENDS}")
            return cand
    return "pallas" if not default_interpret() else "pallas_interpret"


# ---------------------------------------------------------------------------
# Tile autotune table
# ---------------------------------------------------------------------------

# regime rows: (name, max flattened batch rows, tile_b, tile_blk, tile_n).
# Measured in interpret/roofline terms (BENCH_serve.json tracks the real
# numbers per PR): decode wants the deepest n tile the VMEM budget allows so
# each streamed code word amortizes over one batch row; prefill wants wide
# batch tiles so the per-tile one-hot build amortizes over many rows.
AUTOTUNE_TABLE = (
    ("decode",  8,    8,   8, 512),
    ("small",   64,   32,  8, 256),
    ("prefill", None, 128, 8, 256),
)

# Measured per-(n, nb)-bucket overrides of the regime table, keyed
# (regime, nb_bucket, n_bucket) with power-of-two buckets.  Populated by
# ``autotune()`` and persisted to autotune_cache.json (``save_autotune_cache``
# / ``autotune(..., write=...)``); loaded back over the static table at
# import when the file exists, so a TPU session's measurements survive.
TUNED_TILES: dict[tuple[str, int, int], tuple[int, int, int]] = {}

AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"


def _default_cache_path() -> str:
    """Anchored default for autotune_cache.json — never the CWD (a stray
    cache in an unrelated working directory must not silently steer
    kernel tiles; $REPRO_AUTOTUNE_CACHE outranks this).  In a src-layout
    checkout (three levels above this module holds pyproject.toml) the
    file lives at the repo root, where a TPU session commits it; for an
    installed package it falls back to a per-user cache dir instead of
    writing into site-packages' parent."""
    root = os.path.abspath(os.path.join(
        os.path.dirname(__file__), os.pardir, os.pardir, os.pardir))
    if os.path.exists(os.path.join(root, "pyproject.toml")):
        return os.path.join(root, "autotune_cache.json")
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-rsr",
                        "autotune_cache.json")


DEFAULT_AUTOTUNE_CACHE = _default_cache_path()

_log = logging.getLogger(__name__)


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _bucket(v: int) -> int:
    """Power-of-two bucket (≥ 1) for the tuned-tile table key."""
    return 1 << max(0, int(v - 1).bit_length())


def _regime(b: int) -> str:
    for name, max_b, *_ in AUTOTUNE_TABLE:
        if max_b is None or b <= max_b:
            return name
    return AUTOTUNE_TABLE[-1][0]


def select_tiles(b: int, nb: int, n: int) -> tuple[int, int, int]:
    """(tile_b, tile_blk, tile_n) for a (B rows, nb blocks, n contraction)
    problem.  Measured per-(n, nb)-bucket entries (TUNED_TILES) outrank the
    static regime row; either choice is shape-clamped (tiles never exceed
    the padded problem: no wasted VMEM on reduced/smoke models)."""
    tuned = TUNED_TILES.get((_regime(b), _bucket(nb), _bucket(n)))
    if tuned is not None:
        tile_b, tile_blk, tile_n = tuned
    else:
        for _, max_b, tile_b, tile_blk, tile_n in AUTOTUNE_TABLE:
            if max_b is None or b <= max_b:
                break
    tile_b = min(tile_b, _round_up(b, 8))
    tile_blk = min(tile_blk, _round_up(nb, 8))
    tile_n = min(tile_n, _round_up(n, 128))
    return tile_b, tile_blk, tile_n


def save_autotune_cache(path: Optional[str] = None) -> str:
    """Dump TUNED_TILES (and the paged-attention query-tile overlay,
    TUNED_ATTN_TILES) to JSON (default: $REPRO_AUTOTUNE_CACHE, else the
    repo-anchored autotune_cache.json) so a hardware session's measurements
    persist.
    The payload records the measuring host backend; loads on different
    hardware are refused (CPU-interpreter tiles must not steer TPU runs)."""
    from repro.kernels.paged_attention import TUNED_ATTN_TILES
    path = path or os.environ.get(AUTOTUNE_CACHE_ENV, DEFAULT_AUTOTUNE_CACHE)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = {
        "schema": "autotune_cache_v1",
        "host_backend": jax.default_backend(),
        "entries": [
            {"regime": r, "nb_bucket": nbb, "n_bucket": nbk,
             "tiles": list(t)}
            for (r, nbb, nbk), t in sorted(TUNED_TILES.items())],
        "attn_entries": [
            {"regime": r, "c_bucket": cb, "tile_c": t}
            for (r, cb), t in sorted(TUNED_ATTN_TILES.items())],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


class AutotuneCacheError(ValueError):
    """A malformed autotune_cache.json.  Raised by
    :func:`validate_autotune_payload` / :func:`load_autotune_cache` BEFORE
    any table mutation, so a bad file can never clear or half-populate
    ``TUNED_TILES`` / ``TUNED_ATTN_TILES``."""


def validate_autotune_payload(payload) -> tuple[dict, dict]:
    """Validate a cache payload; returns ``(tuned, attn_tuned)`` dicts in
    the in-memory table formats.  Checks every entry (known regime names,
    positive integer buckets, tile arity 3 of positive ints, positive
    tile_c) and raises :class:`AutotuneCacheError` naming the first bad
    entry — the whole file is rejected, nothing is applied piecemeal."""
    from repro.kernels.paged_attention import PAGED_ATTN_TILES
    if not isinstance(payload, dict):
        raise AutotuneCacheError(
            f"cache payload must be a JSON object, got "
            f"{type(payload).__name__}")
    regimes = {row[0] for row in AUTOTUNE_TABLE}
    attn_regimes = {row[0] for row in PAGED_ATTN_TILES}

    def _pos_int(v, what, e):
        if isinstance(v, bool) or not isinstance(v, int) or v <= 0:
            raise AutotuneCacheError(
                f"entry {e!r}: {what} must be a positive int, got {v!r}")
        return v

    tuned: dict[tuple[str, int, int], tuple[int, int, int]] = {}
    for e in payload.get("entries", ()):
        if not isinstance(e, dict):
            raise AutotuneCacheError(f"entry {e!r}: expected an object")
        regime = e.get("regime")
        if regime not in regimes:
            raise AutotuneCacheError(
                f"entry {e!r}: unknown regime {regime!r} "
                f"(known: {sorted(regimes)})")
        tiles = e.get("tiles")
        if not isinstance(tiles, (list, tuple)) or len(tiles) != 3:
            raise AutotuneCacheError(
                f"entry {e!r}: tiles must be [tile_b, tile_blk, tile_n], "
                f"got {tiles!r}")
        tiles = tuple(_pos_int(t, "tile", e) for t in tiles)
        key = (str(regime), _pos_int(e.get("nb_bucket"), "nb_bucket", e),
               _pos_int(e.get("n_bucket"), "n_bucket", e))
        tuned[key] = tiles
    attn_tuned: dict[tuple[str, int], int] = {}
    for e in payload.get("attn_entries", ()):
        if not isinstance(e, dict):
            raise AutotuneCacheError(f"attn entry {e!r}: expected an object")
        regime = e.get("regime")
        if regime not in attn_regimes:
            raise AutotuneCacheError(
                f"attn entry {e!r}: unknown regime {regime!r} "
                f"(known: {sorted(attn_regimes)})")
        key = (str(regime), _pos_int(e.get("c_bucket"), "c_bucket", e))
        attn_tuned[key] = _pos_int(e.get("tile_c"), "tile_c", e)
    return tuned, attn_tuned


def load_autotune_cache(path: Optional[str] = None, *, clear: bool = False,
                        force: bool = False) -> int:
    """Load measured tiles over the static table; returns the entry count.
    Called automatically at import when the cache file exists.  Entries
    measured on a different host backend are skipped unless ``force``.
    The default path is $REPRO_AUTOTUNE_CACHE, else the repo-anchored
    DEFAULT_AUTOTUNE_CACHE — never the CWD.  Every applied overlay is
    logged so an operator can tell which file steered the tiles.

    The whole file is validated (:func:`validate_autotune_payload`) before
    the tables are touched: a malformed file raises
    :class:`AutotuneCacheError` and leaves ``TUNED_TILES`` /
    ``TUNED_ATTN_TILES`` exactly as they were (no clear, no partial
    population)."""
    from repro.kernels.paged_attention import TUNED_ATTN_TILES
    path = path or os.environ.get(AUTOTUNE_CACHE_ENV, DEFAULT_AUTOTUNE_CACHE)
    if not os.path.exists(path):
        if clear:
            TUNED_TILES.clear()
            TUNED_ATTN_TILES.clear()
        return 0
    try:
        with open(path) as f:
            payload = json.load(f)
    except json.JSONDecodeError as e:
        raise AutotuneCacheError(f"{path}: not valid JSON ({e})") from e
    try:
        tuned, attn_tuned = validate_autotune_payload(payload)
    except AutotuneCacheError as e:
        raise AutotuneCacheError(f"{path}: {e}") from None
    # validation passed — mutations are safe from here on
    if clear:
        TUNED_TILES.clear()
        TUNED_ATTN_TILES.clear()
    host = payload.get("host_backend")
    if not force and host is not None and host != jax.default_backend():
        _log.info("ignoring autotune cache %s: measured on host backend "
                  "%r, running on %r", path, host, jax.default_backend())
        return 0
    TUNED_TILES.update(tuned)
    TUNED_ATTN_TILES.update(attn_tuned)
    if tuned or attn_tuned:
        _log.info("loaded %d tuned tile entries (+%d paged-attn) over the "
                  "static tables from %s", len(tuned), len(attn_tuned),
                  path)
    return len(tuned) + len(attn_tuned)


# ---------------------------------------------------------------------------
# Implementations
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def _scatter_matmul(xb: jax.Array, codes: jax.Array, k: int) -> jax.Array:
    """Pure-JAX fallback: bucket scatter-add (the core oracle) + Tern_[k]
    product.  (B, n) × (nb, n) codes -> (B, nb·k) fp32.  The scatter updates
    tensor is the irreducible HLO cost of the segmented sum (EXPERIMENTS.md
    SS Perf: the (σ, L) prefix-sum form measured ~20× worse under XLA, the
    chunked one-hot form ~2× worse).
    """
    from repro.core.rsr import segmented_sum_scatter
    u = segmented_sum_scatter(xb, codes, 3 ** k)  # (B, nb, P)
    y = jnp.einsum("bcp,pk->bck", u, binlib.tern_matrix(k, jnp.float32))
    return y.reshape(xb.shape[0], -1)


def rsr_serve_matmul(xb: jax.Array, codes: jax.Array, *, k: int,
                     packed: Optional[jax.Array] = None,
                     scale: Optional[jax.Array] = None,
                     bias: Optional[jax.Array] = None,
                     n_out: Optional[int] = None,
                     backend: Optional[str] = None,
                     tiles: Optional[tuple[int, int, int]] = None
                     ) -> jax.Array:
    """(B, n) activations × ternary-direct code arrays -> (B, n_out) fp32.

    The serve-graph contraction: backend-dispatched, fused epilogue.  `codes`
    is always required (scatter fallback + n/nb shape source); the Pallas
    path streams only `packed` when given.
    """
    b, n = xb.shape
    nb, n_c = codes.shape
    assert n_c == n, (n_c, n)
    n_out = nb * k if n_out is None else n_out
    backend = select_backend(backend)
    xb = xb.astype(jnp.float32)

    if backend == "scatter":
        telemetry.record_dispatch(backend, _regime(b), (0, 0, 0))
        y = _scatter_matmul(xb, codes, k)
        if scale is not None:
            y = y * scale
        y = y[:, :n_out]
        if bias is not None:
            y = y + bias
        return y

    tile_b, tile_blk, tile_n = tiles or select_tiles(b, nb, n)
    # runs at trace time (static shapes): one count per compiled variant
    telemetry.record_dispatch(backend, _regime(b),
                              (tile_b, tile_blk, tile_n))
    x_p = _pad_to(_pad_to(xb, 0, tile_b), 1, tile_n)
    pattern = binlib.tern_matrix(k)
    nb_pad = _round_up(nb, tile_blk)
    bias_full = None
    if bias is not None:
        bias_full = jnp.zeros((nb_pad * k,), jnp.float32).at[:n_out].set(bias)
    if packed is not None:
        per = 4 // jnp.dtype(codes.dtype).itemsize
        words = _pad_to(_pad_to(packed, 0, tile_blk), 1, tile_n // per)
        y = rsr_onehot_matmul(
            x_p, words, pattern, scale=scale, bias=bias_full,
            tile_b=tile_b, tile_blk=tile_blk, tile_n=tile_n,
            packed=True, code_bits=8 * jnp.dtype(codes.dtype).itemsize,
            interpret=(backend == "pallas_interpret"))
    else:
        c_p = _pad_to(_pad_to(codes, 0, tile_blk), 1, tile_n)
        y = rsr_onehot_matmul(
            x_p, c_p, pattern, scale=scale, bias=bias_full,
            tile_b=tile_b, tile_blk=tile_blk, tile_n=tile_n,
            interpret=(backend == "pallas_interpret"))
    return y[:b, :n_out]


def resolve_n_out(p: dict, k: int, nb: int,
                  n_out: Optional[int] = None) -> int:
    """True output width of a serve linear: explicit arg > the shape-encoded
    ``n_out`` marker > bias width > padded nb·k (last resort; wrong whenever
    n_out % k != 0 — the bug the marker exists to fix)."""
    if n_out is not None:
        return n_out
    if "n_out" in p:
        return p["n_out"].shape[-2]
    if "b" in p:
        return p["b"].shape[-1]
    return nb * k


def rsr_serve_linear(p: dict, x: jax.Array, *, cfg,
                     n_out: Optional[int] = None,
                     backend: Optional[str] = None) -> jax.Array:
    """Serve-params dict × (..., n) activations -> (..., n_out) in x.dtype.

    The single entry point every quantized serve linear routes through
    (see module docstring for the params contract and backend semantics).
    """
    codes = p["codes"]
    nb, n = codes.shape
    k = cfg.rsr_k
    n_out = resolve_n_out(p, k, nb, n_out)
    lead = x.shape[:-1]
    xb = x.reshape(-1, n)
    y = rsr_serve_matmul(
        xb, codes, k=k, packed=p.get("packed"),
        scale=p.get("scale"), bias=p.get("b"), n_out=n_out,
        backend=select_backend(backend,
                               getattr(cfg, "rsr_backend", None)))
    return y.reshape(*lead, n_out).astype(x.dtype)


# ---------------------------------------------------------------------------
# Offline autotune (refreshes AUTOTUNE_TABLE candidates with measurements)
# ---------------------------------------------------------------------------

def autotune(b: int, n: int, n_out: int, *, k: int = 5,
             candidates=((8, 8, 256), (8, 8, 512), (32, 8, 256),
                         (128, 8, 256)),
             backend: Optional[str] = None, reps: int = 3,
             write: Union[str, bool, None] = None) -> dict:
    """Measure tile candidates for one (B, n, n_out) linear; returns
    {tiles: best, us: best_us, rows: [(tiles, us), ...], key: tuned-key}.
    The winner is recorded in TUNED_TILES under its (regime, nb, n) bucket —
    subsequent ``select_tiles`` calls for that bucket use it.  ``write``
    persists the whole table to autotune_cache.json (True → default path,
    str → that path), which is loaded back at import on later sessions."""
    from repro.core import preprocess_ternary_direct, random_ternary
    from repro.core.preprocess import pack_code_words
    a = random_ternary(jax.random.PRNGKey(0), (n, n_out))
    idx = preprocess_ternary_direct(a, k)
    packed = pack_code_words(idx.codes)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, n))
    nb = idx.codes.shape[0]
    rows = []
    seen = set()
    for tb, tblk, tn in candidates:
        # clamp (select_tiles-style) rather than skip, so small problems
        # still get a non-empty candidate set; dedupe post-clamp
        tiles = (min(tb, _round_up(b, 8)), min(tblk, _round_up(nb, 8)),
                 min(tn, _round_up(n, 128)))
        if tiles in seen:
            continue
        seen.add(tiles)
        fn = lambda: rsr_serve_matmul(x, idx.codes, k=k, packed=packed,
                                      n_out=n_out, backend=backend,
                                      tiles=tiles)
        fn().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            fn().block_until_ready()
        per_rep_s = (time.perf_counter() - t0) / reps
        telemetry.observe_dispatch_seconds(select_backend(backend),
                                           per_rep_s)
        rows.append((tiles, per_rep_s * 1e6))
    rows.sort(key=lambda r: r[1])
    key = (_regime(b), _bucket(nb), _bucket(n))
    TUNED_TILES[key] = rows[0][0]
    out = {"tiles": rows[0][0], "us": rows[0][1], "rows": rows, "key": key}
    if write:
        out["cache_path"] = save_autotune_cache(
            None if write is True else write)
    return out


# load any persisted measurements over the static table (ROADMAP: a TPU
# session's autotune results must survive the session).  The default path
# is repo-anchored, so importing from an arbitrary CWD cannot pick up a
# stray cache file (the load itself is a no-op when the file is absent).
# A malformed file must not make the package unimportable: log it loudly
# and run on the static tables alone (explicit load_autotune_cache()
# calls still raise AutotuneCacheError).
try:
    load_autotune_cache()
except AutotuneCacheError as _e:
    _log.error("autotune cache rejected, using static tile tables only: "
               "%s", _e)


def _main():
    """Offline autotune CLI:

        python -m repro.kernels.dispatch --shapes 1x4096x4096,256x4096x4096 \\
            --write

    measures each BxNxM shape and (with --write) persists the winners to
    autotune_cache.json, which select_tiles loads over AUTOTUNE_TABLE on
    the next import."""
    import argparse
    ap = argparse.ArgumentParser(description=_main.__doc__)
    ap.add_argument("--shapes", default="1x4096x4096,8x4096x4096,"
                    "64x4096x4096,256x4096x4096",
                    help="comma-separated BxNxM problem shapes")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--backend", default=None)
    ap.add_argument("--write", action="store_true",
                    help="persist measured tiles to the autotune cache")
    ap.add_argument("--out", default=None,
                    help="cache path (default autotune_cache.json)")
    args = ap.parse_args()
    for spec in args.shapes.split(","):
        b, n, m = (int(v) for v in spec.lower().split("x"))
        res = autotune(b, n, m, k=args.k, reps=args.reps,
                       backend=args.backend)
        print(f"{spec}: best={res['tiles']} {res['us']:.1f}us "
              f"key={res['key']}")
    if args.write:
        print("wrote", save_autotune_cache(args.out))


if __name__ == "__main__":
    _main()
