"""Model-FLOPs accounting: MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (fwd),
with the MoE active fraction applied to expert banks (6·N_active·D).

N comes from the abstract train-param tree (path-aware so expert banks can be
scaled by top_k/E); D = tokens processed by the step.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.config import ModelConfig, ShapeConfig


def param_counts(params_abstract, cfg: ModelConfig) -> dict:
    """{'total': N, 'active': N_active (MoE-weighted), 'embed': ...}."""
    total = active = embed = 0
    frac = (cfg.num_experts_per_tok / cfg.num_experts
            if cfg.num_experts else 1.0)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abstract)[0]:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = int(np.prod(leaf.shape))
        total += n
        is_embed = "embed" in keys or "head" in keys
        if is_embed:
            embed += n
        if "moe" in keys and any(k in ("wi", "wg", "wo") for k in keys) \
                and "shared" not in keys:
            active += int(n * frac)
        else:
            active += n
    return {"total": total, "active": active, "embed": embed}


def rsr_scatter_flops(serve_abstract, cfg: ModelConfig, batch: int) -> float:
    """Analytic adds of the RSR segmented-sum scatters (XLA counts scatter as
    0 FLOPs): batch × Σ codes.size, MoE banks weighted by top_k/E."""
    total = 0.0
    frac = (cfg.num_experts_per_tok / cfg.num_experts
            if cfg.num_experts else 1.0)
    for path, leaf in jax.tree_util.tree_flatten_with_path(serve_abstract)[0]:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if keys[-1] != "codes":
            continue
        n = int(np.prod(leaf.shape))
        if "moe" in keys and "shared" not in keys:
            n = int(n * frac)
        total += n
    return float(total) * batch


def model_flops(cfg: ModelConfig, shape: ShapeConfig, counts: dict) -> float:
    """Useful model FLOPs for one step of this shape.

    train  : 6 · N_active · tokens       (fwd+bwd)
    prefill: 2 · N_active · tokens
    decode : 2 · N_active · batch        (one token per sequence)
    (attention score FLOPs excluded — standard 6ND convention.)
    """
    n = counts["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch
