"""TPU v5e hardware constants (per chip) — the roofline denominators."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
PEAK_OPS_INT8 = 394e12          # OP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB
VMEM_BYTES = 128 * 2 ** 20      # ~128 MiB (v5e ~ 128MB VMEM/core)
