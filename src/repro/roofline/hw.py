"""TPU v5e hardware constants (per chip) — the roofline denominators."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
PEAK_OPS_INT8 = 394e12          # OP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB
VMEM_BYTES = 128 * 2 ** 20      # ~128 MiB (v5e ~ 128MB VMEM/core)

# --- static VMEM / tiling model (repro.analysis.tiles rides this) ----------
#
# The compiler owns the full VMEM_BYTES, but a portable Pallas kernel must
# leave room for double-buffered pipelining, spills, and co-resident
# kernels: the static checker budgets a single launch's working set at
# VMEM_KERNEL_BUDGET (the ~16 MB/core figure the Pallas guide plans
# around).  Register tiling quanta: the last block dim is laid out across
# VMEM_LANE lanes and the penultimate dim across 32 / itemsize sublanes
# (8 for f32, 16 for bf16, 32 for int8) — tiles off these quanta pad
# silently at best and fail Mosaic lowering at worst.

VMEM_KERNEL_BUDGET = 16 * 2 ** 20   # per-kernel-launch working-set budget
VMEM_LANE = 128                     # last-dim tile quantum (all dtypes)


def vmem_sublane(itemsize: int) -> int:
    """Penultimate-dim tile quantum for an ``itemsize``-byte dtype."""
    return max(8, 32 // int(itemsize))
