"""Subsystem package."""
