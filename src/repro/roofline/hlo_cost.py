"""Scan-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes/collective traffic by a
factor of ~num_layers (measured 18-32× on this framework's stacked models).
This module re-derives the three roofline numerators directly from the
optimized HLO text with loop multipliers:

  * builds the computation call graph (entry → while bodies/conditions,
    fusions, to_apply reducers),
  * extracts each while loop's trip count from its condition
    (``compare(iter, constant(N))`` pattern emitted by lax.scan),
  * FLOPs: 2·M·N·K per dot/convolution (batch dims included), scaled by the
    product of enclosing trip counts,
  * bytes: per materialized buffer — every non-fusion-internal instruction
    writes its result once and reads its operands once (fusion internals are
    VMEM-resident and excluded),
  * collectives: per-kind ring wire bytes (see analysis.py) × trip counts.

This is a first-order model (no aliasing/donation discount, elementwise
FLOPs ignored) — consistent with how published rooflines are computed.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.roofline.analysis import (_DTYPE_BYTES, _GROUPS_RE, _SHAPE_RE,
                                     _COLLECTIVES, _wire_bytes)

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"^\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                        r"([\w\-]+)\(")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|calls|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_text: str
    line: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes_of(self.result_text)


_PARAM_DECL_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^()]*\))|"
                            r"(?:[a-z0-9]+\[[0-9,]*\]))")


def _parse_computations(hlo: str):
    """-> (comps: name -> [_Instr], entry, shapes: name -> dims tuple)."""
    comps: dict[str, list[_Instr]] = {}
    shapes: dict[str, list[int]] = {}
    cur = None
    entry = None

    def record_shape(name: str, text: str):
        m = _SHAPE_RE.search(text)
        if m:
            dims = [int(d) for d in m.group(2).split(",") if d]
            shapes[name] = dims

    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _COMP_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if stripped.startswith("ENTRY"):
                entry = cur
            # header parameter declarations carry shapes
            for pname, ptext in _PARAM_DECL_RE.findall(stripped):
                record_shape(pname, ptext)
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rest = mi.groups()
        mo = _OPCODE_RE.match(rest)
        if not mo:
            continue
        result_text, opcode = mo.groups()
        record_shape(name, result_text)
        comps[cur].append(_Instr(name, opcode, result_text, line))
    return comps, entry, shapes


def _dot_flops(line: str, result_text: str, shapes: dict) -> float:
    """2 × prod(result dims) × contraction size (lhs operand shape lookup)."""
    out_elems = 1
    rshapes = _SHAPE_RE.findall(result_text)
    if not rshapes:
        return 0.0
    dt, dims = rshapes[0]
    for d in dims.split(","):
        if d:
            out_elems *= int(d)
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    # lhs operand: either typed ("dot(f32[128,128]{1,0} %a, ...)" — newer HLO
    # text) or untyped ("dot(%a, ...)"); prefer the inline shape, fall back
    # to the %name shape table
    mo = re.search(r"\bdot\(\s*(?:([a-z0-9]+\[[0-9,]*\])\S*\s+)?%([\w.\-]+)",
                   line)
    if mc and mo:
        lhs_dims = None
        if mo.group(1):
            md = _SHAPE_RE.match(mo.group(1))
            if md:
                lhs_dims = [int(d) for d in md.group(2).split(",") if d]
        if lhs_dims is None:
            lhs_dims = shapes.get(mo.group(2))
        if lhs_dims:
            for ci in (int(x) for x in mc.group(1).split(",") if x):
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
    return 2.0 * out_elems * k


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "bitcast-convert", "reshape", "iota",
                   "after-all", "partition-id", "replica-id",
                   # control flow results alias their operand buffers —
                   # the traffic is whatever their bodies do, not the carry
                   "while", "conditional", "call"}


def _trip_count(cond_instrs: list[_Instr]) -> int:
    """lax.scan condition: compare(iter, const) — take the max constant."""
    best = 1
    for ins in cond_instrs:
        if ins.opcode == "compare" or "compare(" in ins.line:
            for mm in _CONST_RE.finditer(ins.line):
                best = max(best, int(mm.group(1)))
    if best > 1:
        return best
    for ins in cond_instrs:
        for mm in _CONST_RE.finditer(ins.line):
            best = max(best, int(mm.group(1)))
    return best


def analyze_hlo(hlo: str) -> dict:
    """-> {'flops', 'bytes', 'collectives': {kind: bytes, 'total': ...},
           'loops': [(trip, body_name), ...]} — per device."""
    comps, entry, shapes = _parse_computations(hlo)
    if entry is None:
        entry = next(iter(comps))

    # map: computation -> list of (callee, kind)
    calls = defaultdict(list)
    fusion_internal = set()
    while_info = []      # (caller, body, cond)
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode == "fusion":
                for m in _CALL_RE.finditer(ins.line):
                    for callee in re.split(r",\s*%?", m.group(1)):
                        fusion_internal.add(callee)
            mb = re.search(r"body=%?([\w.\-]+)", ins.line)
            mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
            if ins.opcode == "while" and mb:
                mt = _TRIP_RE.search(ins.line)
                while_info.append((cname, mb.group(1),
                                   mc.group(1) if mc else None,
                                   int(mt.group(1)) if mt else None))

    # compute multiplier per computation: product of trip counts of
    # enclosing while bodies (1-level nesting typical for scan)
    mult = defaultdict(lambda: 1.0)
    loops = []
    for caller, body, cond, known in while_info:
        trip = known if known else (
            _trip_count(comps.get(cond, [])) if cond else 1)
        loops.append((trip, body))
        mult[body] = max(mult[body], float(trip) * mult[caller])
        if cond:
            mult[cond] = mult[body]

    # propagate multipliers through nested calls (fusion/to_apply inherit)
    changed = True
    iters = 0
    while changed and iters < 10:
        changed = False
        iters += 1
        for cname, instrs in comps.items():
            base = mult[cname]
            for ins in instrs:
                for m in _CALL_RE.finditer(ins.line):
                    for callee in re.split(r",\s*%?", m.group(1)):
                        callee = callee.strip().lstrip("%")
                        if callee in comps:
                            tgt = base
                            if ins.opcode == "while":
                                continue        # handled above
                            if mult[callee] < tgt:
                                mult[callee] = tgt
                                changed = True

    flops = 0.0
    byts = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    for cname, instrs in comps.items():
        f = mult[cname]
        in_fusion = cname in fusion_internal
        for ins in instrs:
            if ins.opcode in ("dot", "convolution"):
                flops += f * _dot_flops(ins.line, ins.result_text, shapes)
            if in_fusion:
                continue                        # VMEM-resident
            kind = ins.opcode[:-6] if ins.opcode.endswith("-start") \
                else ins.opcode
            if kind in _COLLECTIVES:
                rb = ins.result_bytes
                gm = _GROUPS_RE.search(ins.line)
                g = int(gm.group(2)) if gm else 2
                coll[kind] += f * _wire_bytes(kind, rb, g)
            if ins.opcode in _SKIP_BYTES_OPS or ins.opcode.endswith("-done"):
                continue
            if "dynamic-update-slice" in ins.line and f > 1:
                # scan carry/ys write: the touched region is the 1/trip
                # slice, not the whole buffer — count the buffer once total
                byts += 2.0 * ins.result_bytes
                continue
            # write result once; reads approximated by operand results
            byts += f * 2.0 * ins.result_bytes
    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    return {"flops": flops, "bytes": byts, "collectives": coll,
            "loops": loops}
