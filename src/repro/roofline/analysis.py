"""Roofline analysis from compiled artifacts (no hardware required).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs      / (chips × PEAK_FLOPS_BF16)
    memory     = HLO_bytes      / (chips × HBM_BW)
    collective = collective_B   / (chips × ICI_BW_PER_LINK)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Calibration
(tests/test_roofline.py) shows XLA reports these PER DEVICE for an SPMD
module — they are used as-is.

Collective bytes are NOT in cost_analysis: we parse the optimized HLO text.
XLA prints collective operands untyped (just %name), so we read the *result*
shape of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction plus its replica_groups=[n,g] group size, and
convert to per-device wire bytes with the standard ring formulas:

    all-gather      out·(g-1)/g          reduce-scatter  out·(g-1)
    all-reduce      2·size·(g-1)/g       all-to-all      size·(g-1)/g
    collective-permute  size

Two adjustments recorded per cell: (a) XLA assigns zero FLOPs to scatter ops,
so RSR-serve cells add the analytic segmented-sum adds (batch × Σ codes.size,
MoE banks weighted by top_k/E) via ``extra_flops``; (b) useful_ratio uses
MODEL_FLOPS/chips against the per-device HLO FLOPs.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# a typed shape like bf16[128,4096]{1,0} or f32[]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# instruction: [ROOT] %name = <result types> <opcode>(
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _wire_bytes(kind: str, result_bytes: float, g: int) -> float:
    """Per-device ring wire bytes for a collective with group size g."""
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return result_bytes          # collective-permute


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes per collective kind from optimized HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _INSTR_RE.match(s)
        if not m:
            continue
        result_part, kind = m.group(1), m.group(2)
        if "-done(" in s:        # -done carries no new transfer
            continue
        rbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(result_part))
        gm = _GROUPS_RE.search(s)
        g = int(gm.group(2)) if gm else 2
        out[kind] += _wire_bytes(kind, rbytes, g)
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


@dataclasses.dataclass
class Roofline:
    """All *_flops/*_bytes fields are PER CHIP; *_s are per-chip step times."""
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float          # 6·N·D (or serve equivalent), per chip
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / hw.PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes / hw.HBM_BW
        self.collective_s = self.coll_bytes / hw.ICI_BW_PER_LINK
        return self

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """model-FLOPs-time / dominant-term-time (≈ achievable MFU bound)."""
        ideal = self.model_flops / hw.PEAK_FLOPS_BF16
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction,
                 bound_s=self.bound_s)
        return d


def extract_cost(compiled) -> tuple[float, float]:
    """(flops, bytes) from compiled.cost_analysis(), tolerant of formats."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return flops, byts


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, hlo_text: Optional[str] = None,
            extra_flops: float = 0.0) -> Roofline:
    """cost_analysis / collective_bytes are already per device (see header);
    model_flops is global and is normalized here.  extra_flops: per-device
    analytic additions (e.g. scatter adds XLA does not count)."""
    flops, byts = extract_cost(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops + extra_flops, hlo_bytes=byts,
                    coll_bytes=coll["total"],
                    model_flops=model_flops / chips).finalize()
