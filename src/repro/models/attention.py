"""Attention variants: MHA/GQA/MQA, sliding-window (banded), MLA (DeepSeek-V2),
and gated cross-attention (Llama-3.2-Vision) — each with a full-sequence path
(train) and a KV-cache path that appends a chunk of C ≥ 1 tokens at per-slot
positions (C == 1 is classic decode; C > 1 is the chunked-prefill hot path).

Full-sequence softmax attention is evaluated flash-style: an online-softmax
scan over KV chunks (peak memory S×C instead of S×S).  Sliding-window
attention uses a banded evaluation — per query chunk only the (window + C)
wide KV band is touched, so FLOPs scale with S·window, not S².

Decode caches:
  gqa  : k, v (B, S_max, KVH, hd) + cross k/v for vlm layers
  local: ring buffer (B, window, KVH, hd), written at pos % window
  mla  : latent c_kv (B, S_max, kv_lora) + k_pe (B, S_max, rope_dim) — the
         MLA compression is preserved in the cache, and decode uses the
         *absorbed* form (W_UK folded into the query, W_UV into the output).

Paged caches (``table`` is not None): the same three caches re-homed into a
global block pool (``repro.serve.paging``).  Layer storage becomes a pool
array with a leading physical-block axis — gqa/local ``(NB+1, KVH, bs, hd)``,
mla ``(NB+1, bs, r)`` — and reads/writes go through the per-slot block
``table`` of physical ids: position p (or ring slot r) writes pool block
``table[b, p // bs]`` at offset ``p % bs``.  Scoring then takes one of two
backends (``paged_backend``, resolved by
``repro.kernels.paged_attention.select_paged_backend``):

* ``kernel`` (default): the Pallas paged-attention kernel scores the
  queries against the pool blocks IN PLACE — the block table drives the
  kernel's KV index maps, softmax accumulates online across blocks, and no
  dense per-slot view is ever materialized (the O(S) HBM win on decode).
* ``gather``: the PR-3 reference — the table's blocks are gathered back
  into the SAME dense (B, KVH, S, hd) view the dense path carries, then
  the identical scoring code runs.  That gather-then-identical-math
  structure is what makes this path bitwise-equal to the dense layout
  (the parity bar in tests/test_serve.py), which is exactly what makes it
  the right debugging reference for the kernel.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels.paged_attention import paged_gqa_attend, paged_mla_attend
from repro.models import modules as nn

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core softmax attention (flash-style chunked)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _gather_blocks(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Paged read: pool (NB, KVH, bs, hd) × table (B, MB) -> the dense
    (B, KVH, MB*bs, hd) view (logical block j of row b is pool[table[b,j]]).
    """
    g = pool[table]                                 # (B, MB, KVH, bs, hd)
    g = jnp.swapaxes(g, 1, 2)                       # (B, KVH, MB, bs, hd)
    b, kvh, mb, bs, hd = g.shape
    return g.reshape(b, kvh, mb * bs, hd)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
         window: int = 0, chunk: int = 1024,
         q_offset: int = 0) -> jax.Array:
    """q (B,Sq,H,dh), k/v (B,Sk,KVH,dh|dv) -> (B,Sq,H,dv).

    Online-softmax over KV chunks; banded when window > 0.
    q_offset: absolute position of q[0] (for decode / banded masks).
    """
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    groups = h // kvh
    scale = 1.0 / math.sqrt(dh)
    q = (q * scale).astype(jnp.float32)

    if sq * sk <= chunk * chunk or sk <= chunk:
        # small: direct
        kk = _repeat_kv(k, groups).astype(jnp.float32)
        vv = _repeat_kv(v, groups).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk)
        s = s + _mask(sq, sk, causal, window, q_offset)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vv).astype(v.dtype)

    if window > 0:
        return _banded(q, k, v, groups, window, chunk, q_offset, causal, dv)
    return _flash(q, k, v, groups, causal, chunk, q_offset, dv)


def _mask(sq, sk, causal, window, q_offset):
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    m = jnp.zeros((sq, sk), jnp.float32)
    if causal:
        m = jnp.where(kj > qi, NEG_INF, m)
    if window > 0:
        m = jnp.where(kj <= qi - window, NEG_INF, m)
    return m


def _flash(q, k, v, groups, causal, chunk, q_offset, dv):
    """Online-softmax scan over KV chunks."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    nchunks = -(-sk // chunk)
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, chunk, k.shape[2], dh).astype(jnp.float32)
    vc = v.reshape(b, nchunks, chunk, v.shape[2], dv).astype(jnp.float32)

    def step(carry, inputs):
        m_prev, l_prev, acc = carry
        kj, vj, j0 = inputs
        kk = _repeat_kv(kj, groups)
        vv = _repeat_kv(vj, groups)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk)          # (B,H,Sq,C)
        qi = jnp.arange(sq)[:, None] + q_offset           # (Sq,1) abs q pos
        kpos = j0 + jnp.arange(chunk)[None, :]            # (1,C) abs k pos
        mask = kpos <= qi if causal else jnp.ones((sq, chunk), bool)
        mask = mask & (kpos < sk)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vv)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    offs = jnp.arange(nchunks) * chunk
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc_t, vc_t, offs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)       # (B,Sq,H,dv)


def _banded(q, k, v, groups, window, chunk, q_offset, causal, dv):
    """Sliding-window: per q-chunk touch only the (window+chunk) KV band."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    band = window + chunk                                  # kv span per q chunk
    nq = -(-sq // chunk)
    padq = nq * chunk - sq
    if padq:
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (band, chunk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (band, chunk), (0, 0), (0, 0)))

    def one_chunk(i):
        q_i = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        # kv band covering positions [i*chunk - window, i*chunk + chunk)
        start = i * chunk                                  # shifted by +band pad
        k_i = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        kk = _repeat_kv(k_i, groups).astype(jnp.float32)
        vv = _repeat_kv(v_i, groups).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_i, kk)
        qi = (i * chunk + jnp.arange(chunk))[:, None] + q_offset
        kj = (i * chunk - window + jnp.arange(band))[None, :] + q_offset
        mask = (kj >= 0) & (kj < sk + q_offset)
        if causal:
            mask = mask & (kj <= qi)
        mask = mask & (kj > qi - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vv)

    out = jax.lax.map(one_chunk, jnp.arange(nq))           # (nq,B,C,H,dv)
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * chunk, h, dv)
    return out[:, :sq].astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA/MQA layer (+ optional sliding window) — params & full/decode apply
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.init_linear(ks[0], cfg.d_model, cfg.num_heads * hd,
                             bias=cfg.qkv_bias, cfg=cfg),
        "wk": nn.init_linear(ks[1], cfg.d_model, cfg.num_kv_heads * hd,
                             bias=cfg.qkv_bias, cfg=cfg),
        "wv": nn.init_linear(ks[2], cfg.d_model, cfg.num_kv_heads * hd,
                             bias=cfg.qkv_bias, cfg=cfg),
        "wo": nn.init_linear(ks[3], cfg.num_heads * hd, cfg.d_model, cfg=cfg),
    }


def gqa_apply(p: dict, x: jax.Array, *, cfg: ModelConfig, lin,
              window: int = 0, positions: Optional[jax.Array] = None,
              cache: Optional[dict] = None, pos: Optional[jax.Array] = None,
              table: Optional[jax.Array] = None,
              paged_backend: str = "gather"):
    """Full-seq when cache is None, else cached chunk step (C = x.shape[1]
    tokens appended at per-slot positions `pos`; C == 1 is classic decode).
    With ``table`` the cache is a paged block pool — writes are indirected
    through the block table, and ``paged_backend`` picks the scoring path:
    the in-place Pallas ``kernel`` or the dense-view ``gather`` reference
    (see module docstring).

    Returns (out, new_cache)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    q = lin(p["wq"], x).reshape(b, s, h, hd)
    k = lin(p["wk"], x).reshape(b, s, kvh, hd)
    v = lin(p["wv"], x).reshape(b, s, kvh, hd)

    if cache is None:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        if not cfg.is_encoder:
            q = nn.apply_rope(q, positions, theta=cfg.rope_theta)
            k = nn.apply_rope(k, positions, theta=cfg.rope_theta)
        out = sdpa(q, k, v, causal=cfg.causal, window=window)
        return lin(p["wo"], out.reshape(b, s, h * hd)), None

    # ---- cached path: C = s new tokens per sequence, per-slot positions ----
    # Cache layout is (B, KVH, S, hd): the score dot contracts the LAST axis
    # and the PV dot contracts S with no transposes — the (B,S,KVH,hd)
    # layout cost two full-cache transpose copies per layer in the lowered
    # HLO (256 MiB/layer on gemma decode; perf_iterations/iter3).
    # Positions are per batch row (continuous batching: slots hold
    # independent sequences), so writes are per-row scatters, not a shared
    # dynamic_update_slice.  C == 1 is the decode step; C > 1 is a prefill
    # chunk whose q/k/v/o projections batch B·C rows through the kernel.
    posv = pos if pos is not None else cache["pos"]           # (B,)
    positions = posv[:, None] + jnp.arange(s)[None, :]        # (B, C) absolute
    q = nn.apply_rope(q, positions, theta=cfg.rope_theta)
    k = nn.apply_rope(k, positions, theta=cfg.rope_theta)
    smax = cache["k"].shape[2]
    groups = h // kvh
    # keep the cache in its storage dtype: upcasting here materializes an
    # f32 copy of the whole cache (XLA hoists the convert out of the layer
    # scan — measured 1.15 GB/step on gemma decode, perf_iterations/iter2).

    if window > 0:
        # Ring buffer: a chunk's writes can wrap the window and evict keys
        # an earlier in-chunk query still needs, so the write/attend core
        # stays per-step (the single-token decode computation under
        # lax.scan) while the projections above/below run batched.  Paged
        # mode carries the POOL arrays through the scan and indirects each
        # per-step write/read through the ring slice of the block table —
        # the ring length (mb_ring * block_size) equals the dense ring, so
        # the slot arithmetic and masks are unchanged.
        blk_sz = cache["k"].shape[2] if table is not None else 0
        slots = table.shape[1] * blk_sz if table is not None else smax

        def step(carry, inp):
            ck, cv = carry
            kt, vt, qt, pt = inp           # (b,kvh,hd) ×2, (b,h,hd), (b,)
            slot_t = pt % slots
            if table is not None:
                blk = jnp.take_along_axis(
                    table, (slot_t // blk_sz)[:, None], axis=1)[:, 0]
                ck = ck.at[blk, :, slot_t % blk_sz].set(kt.astype(ck.dtype))
                cv = cv.at[blk, :, slot_t % blk_sz].set(vt.astype(cv.dtype))
                if paged_backend == "kernel":
                    # in-place scoring over the ring blocks: no dense view
                    qk = (qt[:, None] / math.sqrt(hd)).astype(ck.dtype)
                    ot = paged_gqa_attend(qk, ck, cv, table, pt[:, None],
                                          ring_slots=slots)[:, 0]
                    return (ck, cv), ot.reshape(b, kvh, groups, hd)
                ckd = _gather_blocks(ck, table)
                cvd = _gather_blocks(cv, table)
            else:
                ck = ck.at[jnp.arange(b), :, slot_t].set(kt.astype(ck.dtype))
                cv = cv.at[jnp.arange(b), :, slot_t].set(vt.astype(cv.dtype))
                ckd, cvd = ck, cv
            qg = (qt / math.sqrt(hd)).astype(ck.dtype)
            qg = qg.reshape(b, kvh, groups, hd)            # group by kv head
            s_ = jnp.einsum("bhgd,bhkd->bhgk", qg, ckd,
                            preferred_element_type=jnp.float32)
            kpos = jnp.arange(slots)[None, :]
            # valid = last min(pos+1, window) slots
            age = (pt[:, None] - kpos) % slots
            valid = (age >= 0) & (age < jnp.minimum(pt[:, None] + 1, slots))
            valid = valid & ((pt[:, None] - age) >= 0)
            s_ = jnp.where(valid[:, None, None, :], s_, NEG_INF)
            pr = jax.nn.softmax(s_, axis=-1).astype(cv.dtype)
            ot = jnp.einsum("bhgk,bhkd->bhgd", pr, cvd,
                            preferred_element_type=jnp.float32)
            return (ck, cv), ot

        (ck, cv), outs = jax.lax.scan(
            step, (cache["k"], cache["v"]),
            (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
             jnp.moveaxis(q, 1, 0), jnp.moveaxis(positions, 1, 0)))
        out = jnp.moveaxis(outs, 0, 1).astype(x.dtype)     # (b,C,kvh,g,hd)
        out = lin(p["wo"], out.reshape(b, s, h * hd))
        return out, {"k": ck, "v": cv}

    if table is not None:
        # paged write: position p of row b lands in pool block
        # table[b, p // bs] at offset p % bs
        blk_sz = cache["k"].shape[2]
        blk = jnp.take_along_axis(table, positions // blk_sz, axis=1)
        off = positions % blk_sz
        ck = cache["k"].at[blk, :, off].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[blk, :, off].set(v.astype(cache["v"].dtype))
        if paged_backend == "kernel":
            # score in place over the pool blocks (online softmax through
            # the table); the dense view below is never built
            qk = (q / math.sqrt(hd)).astype(ck.dtype)
            out = paged_gqa_attend(qk, ck, cv, table, positions)
            out = lin(p["wo"], out.astype(x.dtype).reshape(b, s, h * hd))
            return out, {"k": ck, "v": cv}
        # gather reference: the table's blocks materialized back into the
        # dense view the scoring code expects (bitwise-equal to dense)
        ckd = _gather_blocks(ck, table)
        cvd = _gather_blocks(cv, table)
        smax = ckd.shape[2]
    else:
        b_idx = jnp.arange(b)[:, None]
        ck = cache["k"].at[b_idx, :, positions].set(
            k.astype(cache["k"].dtype))
        cv = cache["v"].at[b_idx, :, positions].set(
            v.astype(cache["v"].dtype))
        ckd, cvd = ck, cv
    qg = (q / math.sqrt(hd)).astype(ck.dtype)      # (b,C,h,hd)
    qg = qg.reshape(b, s, kvh, groups, hd)         # group by kv head
    s_ = jnp.einsum("bchgd,bhkd->bchgk", qg, ckd,
                    preferred_element_type=jnp.float32)   # (b,C,kvh,g,S)
    kpos = jnp.arange(smax)[None, None, :]
    mask = kpos <= positions[:, :, None]                  # (b,C,S) causal
    s_ = jnp.where(mask[:, :, None, None, :], s_, NEG_INF)
    pr = jax.nn.softmax(s_, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bchgk,bhkd->bchgd", pr, cvd,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = lin(p["wo"], out.reshape(b, s, h * hd))
    return out, {"k": ck, "v": cv}


def init_gqa_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                   window: int = 0, abstract: bool = False, layout=None):
    hd = cfg.resolved_head_dim
    if layout is not None:             # paged pool (+1 trash block, see
        shape = (layout.num_blocks + 1, cfg.num_kv_heads,   # serve.paging)
                 layout.block_size, hd)
    else:
        slots = min(max_seq, window) if window > 0 else max_seq
        shape = (batch, cfg.num_kv_heads, slots, hd)  # (B,H,S,D) — see decode
    dt = jnp.dtype(cfg.dtype)
    if abstract:
        return {"k": jax.ShapeDtypeStruct(shape, dt),
                "v": jax.ShapeDtypeStruct(shape, dt)}
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — latent KV cache, absorbed decode
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> dict:
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": nn.init_linear(ks[0], cfg.d_model, h * (dn + dr), cfg=cfg),
        "w_dkv": nn.init_linear(ks[1], cfg.d_model, r, cfg=cfg),
        "w_kpe": nn.init_linear(ks[2], cfg.d_model, dr, cfg=cfg),
        "kv_norm": nn.init_norm(r, cfg),
        "w_uk": nn.init_linear(ks[3], r, h * dn, cfg=cfg),
        "w_uv": nn.init_linear(ks[4], r, h * dv, cfg=cfg),
        "wo": nn.init_linear(ks[5], h * dv, cfg.d_model, cfg=cfg),
    }


def mla_apply(p: dict, x: jax.Array, *, cfg: ModelConfig, lin,
              cache: Optional[dict] = None, pos: Optional[jax.Array] = None,
              table: Optional[jax.Array] = None,
              paged_backend: str = "gather"):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    q = lin(p["wq"], x).reshape(b, s, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    c_kv = nn.norm_apply(p["kv_norm"], lin(p["w_dkv"], x), cfg=cfg)  # (b,s,r)
    k_pe = lin(p["w_kpe"], x).reshape(b, s, 1, dr)

    if cache is None:
        positions = jnp.arange(s)[None, :]
        q_pe = nn.apply_rope(q_pe, positions, theta=cfg.rope_theta)
        k_pe = nn.apply_rope(k_pe, positions, theta=cfg.rope_theta)
        k_nope = lin(p["w_uk"], c_kv).reshape(b, s, h, dn)
        v = lin(p["w_uv"], c_kv).reshape(b, s, h, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, s, h, dr))],
                            axis=-1)
        qq = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = sdpa(qq, k, v, causal=cfg.causal)
        return lin(p["wo"], out.reshape(b, s, h * dv)), None

    # ---- absorbed cached path (C = s tokens, per-slot positions) ----
    posv = pos if pos is not None else cache["pos"]           # (B,)
    positions = posv[:, None] + jnp.arange(s)[None, :]        # (B, C)
    q_pe = nn.apply_rope(q_pe, positions, theta=cfg.rope_theta)
    k_pe = nn.apply_rope(k_pe, positions, theta=cfg.rope_theta)
    # absorb W_UK into q:  q_lat[b,c,h,r] = Σ_dn q_nope · W_UK[r, h*dn]
    # (cache stays in storage dtype — see gqa_apply decode note)
    w_uk = p["w_uk"]["w"].reshape(r, h, dn)
    q_lat = jnp.einsum("bchd,rhd->bchr", q_nope.astype(w_uk.dtype),
                       w_uk, preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(dn + dr)
    if table is not None:
        # paged latent cache: pools (NB+1, bs, r) / (NB+1, bs, dr); write
        # through the block table
        blk_sz = cache["c_kv"].shape[1]
        blk = jnp.take_along_axis(table, positions // blk_sz, axis=1)
        off = positions % blk_sz
        c_cache = cache["c_kv"].at[blk, off].set(
            c_kv.astype(cache["c_kv"].dtype))
        pe_cache = cache["k_pe"].at[blk, off].set(
            k_pe[:, :, 0].astype(cache["k_pe"].dtype))
        if paged_backend == "kernel":
            # score in place over the latent pool blocks; W_UV applies to
            # the kernel's latent output in the shared epilogue below
            o_lat = paged_mla_attend(
                q_lat.astype(c_cache.dtype), q_pe.astype(pe_cache.dtype),
                c_cache, pe_cache, table, positions, scale=scale)
            c_d = None                     # dense views never built
        else:
            # gather reference: dense (B, S, ·) views of the table's blocks
            c_d = c_cache[table].reshape(b, -1, r)
            pe_d = pe_cache[table].reshape(b, -1, dr)
    else:
        b_idx = jnp.arange(b)[:, None]
        c_cache = cache["c_kv"].at[b_idx, positions].set(
            c_kv.astype(cache["c_kv"].dtype))
        pe_cache = cache["k_pe"].at[b_idx, positions].set(
            k_pe[:, :, 0].astype(cache["k_pe"].dtype))
        c_d, pe_d = c_cache, pe_cache
    if c_d is not None:
        s_lat = jnp.einsum("bchr,bkr->bchk", q_lat.astype(c_d.dtype),
                           c_d, preferred_element_type=jnp.float32)
        s_pe = jnp.einsum("bchd,bkd->bchk", q_pe.astype(pe_d.dtype),
                          pe_d, preferred_element_type=jnp.float32)
        s_ = (s_lat + s_pe) * scale
        mask = (jnp.arange(c_d.shape[1])[None, None, :]
                <= positions[:, :, None])                     # (B,C,S)
        s_ = jnp.where(mask[:, :, None, :], s_, NEG_INF)
        pr = jax.nn.softmax(s_, axis=-1).astype(c_d.dtype)
        o_lat = jnp.einsum("bchk,bkr->bchr", pr, c_d,
                           preferred_element_type=jnp.float32)
    w_uv = p["w_uv"]["w"].reshape(r, h, dv)
    out = jnp.einsum("bchr,rhd->bchd", o_lat.astype(w_uv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    out = lin(p["wo"], out.reshape(b, s, h * dv).astype(x.dtype))
    return out, {"c_kv": c_cache, "k_pe": pe_cache}


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                   abstract: bool = False, layout=None):
    dt = jnp.dtype(cfg.dtype)
    if layout is not None:             # paged pools (+1 trash block)
        s1 = (layout.num_blocks + 1, layout.block_size, cfg.kv_lora_rank)
        s2 = (layout.num_blocks + 1, layout.block_size, cfg.qk_rope_head_dim)
    else:
        s1 = (batch, max_seq, cfg.kv_lora_rank)
        s2 = (batch, max_seq, cfg.qk_rope_head_dim)
    if abstract:
        return {"c_kv": jax.ShapeDtypeStruct(s1, dt),
                "k_pe": jax.ShapeDtypeStruct(s2, dt)}
    return {"c_kv": jnp.zeros(s1, dt), "k_pe": jnp.zeros(s2, dt)}


# ---------------------------------------------------------------------------
# Gated cross-attention (Llama-3.2-Vision style)
# ---------------------------------------------------------------------------

def init_cross(key, cfg: ModelConfig) -> dict:
    p = init_gqa(key, cfg)
    p["gate"] = jnp.zeros((), jnp.float32)
    return p


def cross_apply(p: dict, x: jax.Array, kv_feats: Optional[jax.Array], *,
                cfg: ModelConfig, lin, cache: Optional[dict] = None):
    """kv_feats (B, T_img, d) at prefill; cached k/v at decode."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    q = lin(p["wq"], x).reshape(b, s, h, hd)
    if cache is None:
        k = lin(p["wk"], kv_feats).reshape(b, -1, kvh, hd)
        v = lin(p["wv"], kv_feats).reshape(b, -1, kvh, hd)
        new_cache = None
    else:
        k, v = cache["xk"], cache["xv"]
        new_cache = cache
    out = sdpa(q, k, v, causal=False)
    out = lin(p["wo"], out.reshape(b, s, h * hd))
    gate = jnp.tanh(p["gate"]).astype(x.dtype)
    return out * gate, new_cache


def init_cross_cache(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.num_image_tokens, cfg.num_kv_heads, hd)
    dt = jnp.dtype(cfg.dtype)
    if abstract:
        return {"xk": jax.ShapeDtypeStruct(shape, dt),
                "xv": jax.ShapeDtypeStruct(shape, dt)}
    return {"xk": jnp.zeros(shape, dt), "xv": jnp.zeros(shape, dt)}
