"""State-space mixers: Mamba-2 (SSD, arXiv:2405.21060) and RG-LRU (Griffin,
arXiv:2402.19427).

Both provide a full-sequence path (train) and a cached path with explicit
recurrent state that advances C ≥ 1 steps per call: C == 1 is O(1)-per-token
decode — which is what makes the long_500k decode shape runnable for these
families (state size is context-independent) — and C > 1 is the chunked
prefill, where the projections batch B·C rows through the quantized kernel
and only the tiny elementwise recurrence stays sequential.

Mamba-2 sequence path = chunked SSD: intra-chunk quadratic (attention-like)
term + inter-chunk linear recurrence over chunk states (lax.scan).
RG-LRU sequence path = associative scan over the diagonal linear recurrence.

Paged-KV contract (PR 3): recurrent/conv states are position-free and
context-length-independent, so they stay PER-SLOT (batch-leading leaves)
under the block-paged cache — only attention KV moves into the global block
pool.  These mixers therefore ignore the block table entirely; they only
need their cache leaves to ride along through ``tfm.slot_cache`` /
``update_slot_cache`` row slicing, which treats every non-pool leaf as
batch-leading.  (This is also why shared-prefix reuse is gated OFF for
SSM/hybrid families: a content-hash of prompt blocks cannot address the
recurrent state at the shared boundary — see
``repro.serve.paging.prefix_sharing_supported`` and the ROADMAP follow-on.)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as nn


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig) -> dict:
    d_in = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    ks = jax.random.split(key, 7)
    cw = 1.0 / math.sqrt(cfg.conv_width)

    def conv_params(dim):
        return (jax.random.normal(jax.random.fold_in(ks[1], dim),
                                  (cfg.conv_width, dim)) * cw
                ).astype(jnp.float32)

    return {
        # one projection PER ROLE (z/x/B/C/dt) and one depthwise conv per
        # conv'd role: the reference fused in_proj + concat'd conv force a
        # split/concat of TP-sharded activations, i.e. a resharding
        # collective-permute of the whole residual stream per layer per
        # direction (measured 3×4 GiB/layer-step on mamba2 train_4k,
        # EXPERIMENTS §Perf).  Role-separated params shard independently and
        # the layer lowers with zero resharding.
        "z_proj": nn.init_linear(ks[0], cfg.d_model, d_in, cfg=cfg),
        "x_proj": nn.init_linear(ks[2], cfg.d_model, d_in, cfg=cfg),
        "b_proj": nn.init_linear(ks[3], cfg.d_model, n, cfg=cfg),
        "c_proj": nn.init_linear(ks[4], cfg.d_model, n, cfg=cfg),
        "dt_proj": nn.init_linear(ks[5], cfg.d_model, h, cfg=cfg),
        "conv_wx": conv_params(d_in),
        "conv_bx": jnp.zeros((d_in,), jnp.float32),
        "conv_wb": conv_params(n),
        "conv_bb": jnp.zeros((n,), jnp.float32),
        "conv_wc": conv_params(n),
        "conv_bc2": jnp.zeros((n,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": nn.init_norm(d_in, cfg),
        "out_proj": nn.init_linear(ks[6], d_in, cfg.d_model, cfg=cfg),
    }


def _causal_conv_seq(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, x (B,S,C), w (W,C) -> (B,S,C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def _conv_chunk_cached(buf: jax.Array, cur: jax.Array, w: jax.Array,
                       b: jax.Array):
    """Depthwise causal conv over a C-step chunk with the (B, W-1, ch) cache
    buffer as left context.  cur (B,C,ch) -> (out (B,C,ch), new buffer)."""
    s = cur.shape[1]
    win = jnp.concatenate([buf, cur], axis=1)          # (B, W-1+C, ch)
    width = w.shape[0]
    wins = jnp.stack([win[:, t:t + width] for t in range(s)], axis=1)
    out = jax.nn.silu(jnp.einsum("bcwk,wk->bck", wins, w) + b)
    return out, win[:, s:]


def _ssd_chunked(xh, dt, A, B, C, chunk: int):
    """Chunked SSD.  xh (b,s,h,p), dt (b,s,h), A (h,), B/C (b,s,n).

    Returns y (b,s,h,p) and final state (b,h,p,n).
    """
    b, s, h, p = xh.shape
    n = B.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    xc = xh.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = B.reshape(b, nc, L, n)
    Cc = C.reshape(b, nc, L, n)

    dA = dtc * (-jnp.exp(A))[None, None, None, :]          # (b,nc,L,h) ≤ 0
    cs = jnp.cumsum(dA, axis=2)                            # within-chunk cumsum
    xdt = xc * dtc[..., None]

    # intra-chunk: y[l] = Σ_{m<=l} exp(cs[l]-cs[m]) (C[l]·B[m]) xdt[m]
    mask = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    delta = cs[:, :, :, None, :] - cs[:, :, None, :, :]           # (b,nc,L,M,h)
    decay = jnp.exp(jnp.where(mask, delta, -jnp.inf))
    cb = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)
    att = cb[..., None] * decay
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", att, xdt)

    # chunk states: state_c = Σ_m exp(cs[L-1]-cs[m]) B[m] ⊗ xdt[m]
    tail = jnp.exp(cs[:, :, -1:, :] - cs)                  # (b,nc,L,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, tail, xdt)

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(cs[:, :, -1, :])                 # (b,nc,h)

    def step(carry, inp):
        st_prev = carry                                    # (b,h,p,n)
        st_c, dec_c = inp
        st_new = st_prev * dec_c[..., None, None] + st_c
        return st_new, st_prev

    st0 = jnp.zeros((b, h, p, n), xh.dtype)
    final, prevs = jax.lax.scan(
        step, st0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prevs, 0, 1)                # (b,nc,h,p,n)

    # inter-chunk output: y[l] += exp(cs[l]) C[l] · state_prev
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         Cc, jnp.exp(cs), prev_states)
    y = (y_intra + y_inter).reshape(b, nc * L, h, p)[:, :s]
    return y, final


def mamba2_apply(p: dict, x: jax.Array, *, cfg: ModelConfig, lin,
                 cache: Optional[dict] = None,
                 pos: Optional[jax.Array] = None):
    """x (B,S,d) -> (B,S,d).  cache = {'state': (B,H,P,N), 'conv': (B,W-1,C)}."""
    b, s, _ = x.shape
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    z = lin(p["z_proj"], x)
    xin = lin(p["x_proj"], x).astype(jnp.float32)
    Bv = lin(p["b_proj"], x).astype(jnp.float32)
    Cv = lin(p["c_proj"], x).astype(jnp.float32)
    dt = lin(p["dt_proj"], x)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (b,s,h)

    if cache is None:
        xin = _causal_conv_seq(xin, p["conv_wx"], p["conv_bx"])
        Bv = _causal_conv_seq(Bv, p["conv_wb"], p["conv_bb"])
        Cv = _causal_conv_seq(Cv, p["conv_wc"], p["conv_bc2"])
        new_conv = None
    else:
        # cached chunk of C = s steps: the conv buffer is the left context
        xin, cx = _conv_chunk_cached(cache["conv_x"], xin,
                                     p["conv_wx"], p["conv_bx"])
        Bv, cb = _conv_chunk_cached(cache["conv_b"], Bv,
                                    p["conv_wb"], p["conv_bb"])
        Cv, cc = _conv_chunk_cached(cache["conv_c"], Cv,
                                    p["conv_wc"], p["conv_bc2"])
        new_conv = {"conv_x": cx, "conv_b": cb, "conv_c": cc}
    xh = xin.reshape(b, s, h, ph)

    if cache is None:
        y, _ = _ssd_chunked(xh, dt, p["A_log"], Bv, Cv, cfg.ssm_chunk)
        new_cache = None
    else:
        # step recurrence scanned over the chunk: st = st*exp(dt*A) + dt·B⊗x.
        # Sequential on purpose — bitwise-identical to repeated single-token
        # decode (chunked-prefill parity anchor); the state update is tiny
        # next to the batched B·C-row projections above.
        dAl = (-jnp.exp(p["A_log"]))                                # (h,)

        def rec_step(st, inp):
            dtt, Bt, Ct, xt = inp           # (b,h) (b,n) (b,n) (b,h,p)
            dA = jnp.exp(dtt * dAl[None])
            st = st * dA[..., None, None] + jnp.einsum(
                "bh,bn,bhp->bhpn", dtt, Bt, xt)
            yt = jnp.einsum("bn,bhpn->bhp", Ct, st)
            return st, yt

        st, ys = jax.lax.scan(
            rec_step, cache["state"],
            (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bv, 1, 0),
             jnp.moveaxis(Cv, 1, 0), jnp.moveaxis(xh, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)                          # (b,C,h,p)
        new_cache = {"state": st, **new_conv}

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = nn.norm_apply(p["norm"], y * jax.nn.silu(z), cfg=cfg)       # gated norm
    return lin(p["out_proj"], y), new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    h, ph, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w1 = cfg.conv_width - 1
    shapes = {"state": (batch, h, ph, n),
              "conv_x": (batch, w1, cfg.d_inner),
              "conv_b": (batch, w1, n),
              "conv_c": (batch, w1, n)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(v, jnp.float32)
                for k, v in shapes.items()}
    return {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

_LRU_C = 8.0


def init_rglru(key, cfg: ModelConfig) -> dict:
    d_rnn = cfg.d_rnn or cfg.d_model
    ks = jax.random.split(key, 7)
    # Λ init so that a = sigmoid(Λ)^c ∈ [0.9, 0.999]
    u = jax.random.uniform(ks[4], (d_rnn,), minval=0.9, maxval=0.999)
    lam = jnp.log((u ** (1.0 / _LRU_C)) / (1 - u ** (1.0 / _LRU_C)))
    return {
        "wx": nn.init_linear(ks[0], cfg.d_model, d_rnn, cfg=cfg),
        "wgate": nn.init_linear(ks[1], cfg.d_model, d_rnn, cfg=cfg),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, d_rnn)) *
                   (1.0 / math.sqrt(cfg.conv_width))).astype(jnp.float32),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_a": nn.init_linear(ks[3], d_rnn, d_rnn, cfg=cfg),   # recurrence gate
        "w_i": nn.init_linear(ks[5], d_rnn, d_rnn, cfg=cfg),   # input gate
        "lam": lam.astype(jnp.float32),
        "out": nn.init_linear(ks[6], d_rnn, cfg.d_model, cfg=cfg),
    }


def rglru_apply(p: dict, x: jax.Array, *, cfg: ModelConfig, lin,
                cache: Optional[dict] = None,
                pos: Optional[jax.Array] = None):
    """Griffin recurrent block. cache = {'h': (B,d_rnn), 'conv': (B,W-1,d_rnn)}."""
    b, s, _ = x.shape
    gate = jax.nn.gelu(lin(p["wgate"], x))
    u = lin(p["wx"], x).astype(jnp.float32)

    if cache is None:
        u = _causal_conv_seq(u, p["conv_w"], p["conv_b"])
        new_conv = None
    else:
        u, new_conv = _conv_chunk_cached(cache["conv"], u,
                                         p["conv_w"], p["conv_b"])

    r = jax.nn.sigmoid(lin(p["w_a"], u))                   # recurrence gate
    i = jax.nn.sigmoid(lin(p["w_i"], u))                   # input gate
    log_a = -_LRU_C * r * jax.nn.softplus(-p["lam"])       # log σ(Λ)^(c·r)
    a = jnp.exp(log_a)
    bt = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)

    if cache is None:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b2 + a2 * b1
        A, Bs = jax.lax.associative_scan(combine, (a, bt), axis=1)
        h = Bs                                             # h_0 = 0
        new_cache = None
    else:
        # sequential over the chunk — bitwise-identical to repeated
        # single-token decode (chunked-prefill parity anchor); the gate /
        # conv / in-out projections above stay batched over B·C rows.
        def rec_step(hprev, ab):
            at, btt = ab
            hnew = at * hprev + btt
            return hnew, hnew

        hlast, hs = jax.lax.scan(rec_step, cache["h"],
                                 (jnp.moveaxis(a, 1, 0),
                                  jnp.moveaxis(bt, 1, 0)))
        h = jnp.moveaxis(hs, 0, 1)                         # (b, C, d)
        new_cache = {"h": hlast, "conv": new_conv}

    y = (h.astype(x.dtype) * gate)
    return lin(p["out"], y), new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    d_rnn = cfg.d_rnn or cfg.d_model
    s1 = (batch, d_rnn)
    s2 = (batch, cfg.conv_width - 1, d_rnn)
    if abstract:
        return {"h": jax.ShapeDtypeStruct(s1, jnp.float32),
                "conv": jax.ShapeDtypeStruct(s2, jnp.float32)}
    return {"h": jnp.zeros(s1, jnp.float32), "conv": jnp.zeros(s2, jnp.float32)}
