"""Parameter-dict module library (functional, flax-free).

Every layer is an (init, apply) pair over plain nested dicts of jnp arrays.

Quantized linears have two parameterizations:
  * train/QAT:  {'w': (n_in, n_out) [, 'b']} — forward applies BitNet-b1.58
                straight-through absmean ternary quantization, so checkpoints
                are RSR-preprocessable after training.
  * serve/RSR:  {'codes': (nb, n_in) uint8/16, 'packed': (nb, ⌈n_in/per⌉)
                uint32, 'scale': (), 'n_out': (n_out, 0) marker [, 'b']} —
                the paper's index replaces the weight matrix entirely.
                Applied through the backend dispatcher
                (repro.kernels.dispatch.rsr_serve_linear): the Pallas one-hot
                kernel streams the word-packed codes (≈1.6 bits/weight at
                k=5) with scale/bias fused into its epilogue; a pure-JAX
                bucket-scatter fallback serves non-Pallas contexts.  The
                ``n_out`` entry is a zero-size shape marker carrying the true
                output width statically (codes cover ⌈n_out/k⌉·k padded
                columns, so n_out is NOT recoverable from the code array when
                n_out % k != 0).

``serve_params_from_train`` converts a trained pytree; ``abstract`` variants
produce ShapeDtypeStructs for the dry-run (no allocation).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import binlib
from repro.core.preprocess import pack_code_words, preprocess_ternary_direct
from repro.core.ternary import absmean_quantize, ste_ternary
from repro.kernels.dispatch import rsr_serve_linear

Param = dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def init_linear(key, n_in: int, n_out: int, *, bias: bool = False,
                cfg: ModelConfig) -> Param:
    scale = 1.0 / math.sqrt(n_in)
    p = {"w": (jax.random.normal(key, (n_in, n_out)) * scale).astype(_dtype(cfg))}
    if bias:
        p["b"] = jnp.zeros((n_out,), _dtype(cfg))
    return p


def linear_apply(p: Param, x: jax.Array, *, cfg: ModelConfig,
                 quantize: bool = True) -> jax.Array:
    """Train/dense path; STE ternary quant when cfg.quant == 'ternary'."""
    if "codes" in p:                      # serve pytree routed here defensively
        return rsr_linear_apply(p, x, cfg=cfg)
    w = p["w"]
    if quantize and cfg.quant == "ternary":
        w = ste_ternary(w.astype(jnp.float32)).astype(w.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"]
    return y


# --- RSR serve parameterization --------------------------------------------

def rsr_num_blocks(n_out: int, k: int) -> int:
    return -(-n_out // k)


def rsr_packed_width(n_in: int, k: int) -> tuple[int, int]:
    """(words, codes_per_word) of the word-packed code array for a linear."""
    per = 4 // jnp.dtype(binlib.code_dtype(3 ** k)).itemsize
    return -(-n_in // per), per


def serve_linear_params(p: Param, *, cfg: ModelConfig) -> Param:
    """Trained {'w'} -> RSR index {'codes','packed','scale','n_out'[,'b']}
    (Algorithm 1 + packed-code layout).

    ``codes`` is the per-row base-3 code array (the scatter fallback's input
    and the σ/L-recoverable canonical form: σ = argsort(codes), L =
    cumsum(hist(codes))); ``packed`` is pack_code_words(codes) — the ONLY
    weight-side array the Pallas serve path streams from HBM (≈1.6
    bits/weight at k=5).  ``n_out`` is a zero-size (n_out, 0) marker whose
    shape carries the true output width through jit/vmap/scan statically.
    Evaluation-strategy measurements live in EXPERIMENTS.md SS Perf iter 5-6:
    the Eq. 5 prefix-sum lowering costs ~20x more HBM traffic under XLA, so
    the non-kernel fallback uses the bucket-scatter contraction.
    """
    w = p["w"].astype(jnp.float32)
    wt, gamma = absmean_quantize(w)
    idx = preprocess_ternary_direct(wt, cfg.rsr_k)
    out = {"codes": idx.codes, "packed": pack_code_words(idx.codes),
           "scale": gamma,
           "n_out": jnp.zeros((w.shape[1], 0), jnp.uint8)}
    if "b" in p:
        out["b"] = p["b"]
    return out


def abstract_serve_linear(n_in: int, n_out: int, *, bias: bool = False,
                          cfg: ModelConfig) -> Param:
    nb = rsr_num_blocks(n_out, cfg.rsr_k)
    nw, _ = rsr_packed_width(n_in, cfg.rsr_k)
    p = {"codes": jax.ShapeDtypeStruct((nb, n_in),
                                       binlib.code_dtype(3 ** cfg.rsr_k)),
         "packed": jax.ShapeDtypeStruct((nb, nw), jnp.uint32),
         "scale": jax.ShapeDtypeStruct((), jnp.float32),
         "n_out": jax.ShapeDtypeStruct((n_out, 0), jnp.uint8)}
    if bias:
        p["b"] = jax.ShapeDtypeStruct((n_out,), jnp.float32)
    return p


def rsr_linear_apply(p: Param, x: jax.Array, *, cfg: ModelConfig,
                     n_out: Optional[int] = None) -> jax.Array:
    """Serve path: x (..., n_in) -> (..., n_out) through the backend
    dispatcher (repro.kernels.dispatch) — Pallas one-hot kernel with
    packed-code streaming and fused scale/bias epilogue on the kernel
    backends, vmapped bucket scatter-add on the fallback.  Backend and tile
    choice are resolved per cfg.rsr_backend / shape (see dispatch module
    docstring)."""
    return rsr_serve_linear(p, x, cfg=cfg, n_out=n_out)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(d: int, cfg: ModelConfig) -> Param:
    p = {"scale": jnp.ones((d,), _dtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _dtype(cfg))
    return p


def norm_apply(p: Param, x: jax.Array, *, cfg: ModelConfig,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"] + p["bias"]).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> Param:
    tbl = jax.random.normal(key, (cfg.vocab_size, cfg.d_model)) * 0.02
    return {"table": tbl.astype(_dtype(cfg))}


def embed_apply(p: Param, tokens: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    if tokens.ndim == 2 and tokens.shape[-1] == 1:
        # decode path: one-hot matmul lookup — with a vocab-sharded table this
        # is a partial matmul + tiny psum instead of an all-gather of the
        # whole table (perf_iterations/iter1).
        oh = jax.nn.one_hot(tokens, p["table"].shape[0],
                            dtype=p["table"].dtype)
        x = oh @ p["table"]
    else:
        x = jnp.take(p["table"], tokens, axis=0)
    if cfg.family in ("dense", "hybrid") and cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)  # gemma scaling
    return x


def head_apply(embed_p: Param, head_p: Optional[Param], x: jax.Array, *,
               cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings or head_p is None:
        return x @ embed_p["table"].T
    return x @ head_p["w"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float) -> jax.Array:
    """x (..., S, H, hd), positions (..., S) -> rotated x (half-split layout)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (dense MLP / GLU)
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Param:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": init_linear(k1, cfg.d_model, d_ff, cfg=cfg),
         "wo": init_linear(k2, d_ff, cfg.d_model, cfg=cfg)}
    if cfg.glu:
        p["wg"] = init_linear(k3, cfg.d_model, d_ff, cfg=cfg)
    return p


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def ffn_apply(p: Param, x: jax.Array, *, cfg: ModelConfig,
              apply_linear=None) -> jax.Array:
    lin = apply_linear or (lambda q, v: linear_apply(q, v, cfg=cfg))
    h = _act(lin(p["wi"], x), cfg.act)
    if "wg" in p:
        h = h * lin(p["wg"], x)
    return lin(p["wo"], h)
