"""Model zoo: composable attention/SSM/MoE layers + scan-stacked transformer."""
