"""Modality frontend stubs.

Per the assignment, [audio]/[vlm] archs specify the transformer BACKBONE only;
the frontend supplies precomputed embeddings.  These helpers generate the
stand-in inputs (concrete for smoke tests, abstract for the dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def audio_frames(cfg: ModelConfig, batch: int, seq: int, *, key=None,
                 abstract: bool = False):
    """HuBERT-style precomputed frame embeddings (B, S, d)."""
    shape = (batch, seq, cfg.d_model)
    dt = jnp.dtype(cfg.dtype)
    if abstract:
        return jax.ShapeDtypeStruct(shape, dt)
    return jax.random.normal(key, shape).astype(dt)


def vision_patches(cfg: ModelConfig, batch: int, *, key=None,
                   abstract: bool = False):
    """Precomputed image patch embeddings (B, T_img, d)."""
    shape = (batch, cfg.num_image_tokens, cfg.d_model)
    dt = jnp.dtype(cfg.dtype)
    if abstract:
        return jax.ShapeDtypeStruct(shape, dt)
    return jax.random.normal(key, shape).astype(dt)
