"""Mixture-of-Experts FFN: top-k routing, GShard grouped capacity dispatch,
optional shared experts (DeepSeek-V2 style), load-balancing aux loss.

Dispatch is the canonical GShard einsum form grouped by batch row: tokens stay
sharded on the data axis (groups = batch), experts shard on the model axis
(EP).  The (g, s, e, c) combine tensor contracts against token activations,
which under pjit lowers to the expected all-to-all between the data-sharded
token layout and the expert-sharded compute layout.

Capacity C = ceil(S · top_k / E · capacity_factor); overflow tokens drop (their
combine weight is zero) — standard GShard semantics.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as nn


def init_moe(key, cfg: ModelConfig) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, e)) * scale
                         ).astype(jnp.float32)},
        "wi": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dt),
        "wg": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, f, d)) / math.sqrt(f)).astype(dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = nn.init_ffn(ks[4], cfg,
                                  d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def capacity(cfg: ModelConfig, s: int) -> int:
    return max(1, int(math.ceil(
        s * cfg.num_experts_per_tok / cfg.num_experts * cfg.capacity_factor)))


GROUP_SIZE = 256     # GShard token-group size (bounds the (g,s,e,c) tensors)


def _group_tokens(x: jax.Array) -> tuple[jax.Array, tuple]:
    """(B, S, d) -> (G, gs, d) with gs <= GROUP_SIZE; returns (grouped, meta)."""
    b, s, d = x.shape
    t = b * s
    gs = min(GROUP_SIZE, t)
    pad = (-t) % gs
    flat = x.reshape(t, d)
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    return flat.reshape(-1, gs, d), (b, s, t, pad)


def _ungroup(y: jax.Array, meta: tuple) -> jax.Array:
    b, s, t, pad = meta
    flat = y.reshape(-1, y.shape[-1])
    if pad:
        flat = flat[:t]
    return flat.reshape(b, s, -1)


def moe_apply(p: dict, x_in: jax.Array, *, cfg: ModelConfig, lin,
              quantize_experts: bool = True):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    x, meta = _group_tokens(x_in)
    g, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    c = capacity(cfg, s)

    logits = (x.astype(jnp.float32) @ p["router"]["w"])       # (g,s,e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)               # (g,s,k)
    top_w = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) assignment within its expert queue
    oh = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)        # (g,s,k,e)
    flat = oh.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                     # exclusive
    pos = pos.reshape(g, s, k, e)
    within = (pos < c) & (oh > 0)
    pos_c = jax.nn.one_hot(jnp.sum(pos * oh, -1).astype(jnp.int32), c,
                           dtype=jnp.float32)                 # (g,s,k,c)
    # combine[g,s,e,c]: routing weight of token (g,s) at slot (e,c)
    combine = jnp.einsum("gske,gskc->gsec",
                         oh * top_w[..., None] * within, pos_c)
    dispatch = (combine > 0).astype(x.dtype)

    # dispatch -> expert compute -> combine (EP all-to-all happens here)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, x)            # (e,g,c,d)
    if cfg.quant == "ternary" and quantize_experts:
        from repro.core.ternary import ste_ternary
        # per-expert absmean scale (matches the per-expert serve indices)
        qt = lambda w: jax.vmap(
            lambda we: ste_ternary(we.astype(jnp.float32)))(w).astype(w.dtype)
    else:
        qt = lambda w: w
    hi = jnp.einsum("egcd,edf->egcf", xe, qt(p["wi"]))
    hg = jnp.einsum("egcd,edf->egcf", xe, qt(p["wg"]))
    h = nn._act(hi, cfg.act) * hg
    ye = jnp.einsum("egcf,efd->egcd", h, qt(p["wo"]))
    y = jnp.einsum("egcd,gsec->gsd", ye, combine.astype(x.dtype))

    if "shared" in p:
        y = y + nn.ffn_apply(p["shared"], x, cfg=cfg, apply_linear=lin)

    # GShard load-balance loss: E · Σ_e f_e · P_e
    me = probs.mean(axis=(0, 1))                              # (e,)
    fe = oh.sum(axis=2).mean(axis=(0, 1))                     # fraction routed
    aux = e * jnp.sum(me * fe)
    return _ungroup(y, meta), aux


# --- serve parameterization (RSR codes per expert) --------------------------

def serve_moe_params(p: dict, *, cfg: ModelConfig) -> dict:
    """Expert banks -> per-expert RSR indices (vmapped Algorithm 1).

    Each bank carries the full serve-linear dict (codes + packed kernel
    stream + scale + n_out marker) stacked over the expert axis."""
    def conv(bank):                                           # (e, n, m)
        return jax.vmap(
            lambda w: nn.serve_linear_params({"w": w}, cfg=cfg))(bank)

    out = {"router": p["router"],
           "wi": conv(p["wi"]), "wg": conv(p["wg"]), "wo": conv(p["wo"])}
    if "shared" in p:
        out["shared"] = {k: nn.serve_linear_params(v, cfg=cfg)
                         for k, v in p["shared"].items()}
    return out


def abstract_serve_moe(cfg: ModelConfig) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff

    def bank(n_in, n_out):
        one = nn.abstract_serve_linear(n_in, n_out, cfg=cfg)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((e, *s.shape), s.dtype), one)

    out = {"router": {"w": jax.ShapeDtypeStruct((d, e), jnp.float32)},
           "wi": bank(d, f), "wg": bank(d, f), "wo": bank(f, d)}
    if cfg.num_shared_experts:
        ff = cfg.moe_d_ff * cfg.num_shared_experts
        out["shared"] = {
            "wi": nn.abstract_serve_linear(d, ff, cfg=cfg),
            "wg": nn.abstract_serve_linear(d, ff, cfg=cfg),
            "wo": nn.abstract_serve_linear(ff, d, cfg=cfg)}
    return out


def moe_apply_serve(p: dict, x_in: jax.Array, *, cfg: ModelConfig):
    """Decode-path MoE with RSR expert banks.

    Routing identical to moe_apply; expert matmuls run through the RSR
    scatter contraction per expert (vmapped over the expert axis).
    """
    x, meta = _group_tokens(x_in)
    g, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    c = capacity(cfg, s)
    logits = (x.astype(jnp.float32) @ p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)
    top_w = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)
    flat = oh.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = pos.reshape(g, s, k, e)
    within = (pos < c) & (oh > 0)
    pos_c = jax.nn.one_hot(jnp.sum(pos * oh, -1).astype(jnp.int32), c,
                           dtype=jnp.float32)
    combine = jnp.einsum("gske,gskc->gsec", oh * top_w[..., None] * within,
                         pos_c)
    dispatch = (combine > 0).astype(x.dtype)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, x)            # (e,g,c,d)

    def expert(pp, xi, n_out):
        # pp: one expert's serve dict (codes/packed/scale); explicit n_out
        # (the stacked n_out marker vmaps fine, but being explicit keeps the
        # per-expert closure shape-free)
        return nn.rsr_linear_apply(pp, xi, cfg=cfg, n_out=n_out)

    def bank_slice(bank):
        return {k: bank[k] for k in ("codes", "packed", "scale")}

    f = cfg.moe_d_ff
    xef = xe.reshape(e, -1, d)
    hi = jax.vmap(lambda pp, xi: expert(pp, xi, f))(bank_slice(p["wi"]), xef)
    hg = jax.vmap(lambda pp, xi: expert(pp, xi, f))(bank_slice(p["wg"]), xef)
    h = nn._act(hi, cfg.act) * hg
    ye = jax.vmap(lambda pp, xi: expert(pp, xi, d))(bank_slice(p["wo"]), h)
    ye = ye.reshape(e, g, c, d)
    y = jnp.einsum("egcd,gsec->gsd", ye, combine.astype(x.dtype))
    if "shared" in p:
        lin = lambda q, v: nn.rsr_linear_apply(q, v, cfg=cfg)
        h2 = nn._act(lin(p["shared"]["wi"], x), cfg.act) * \
            lin(p["shared"]["wg"], x)
        y = y + lin(p["shared"]["wo"], h2)
    return _ungroup(y, meta), jnp.zeros((), jnp.float32)
