"""Model assembly: heterogeneous layer stacks under lax.scan.

Layers are grouped into *superblocks* following cfg.block_pattern (e.g.
recurrentgemma (rec, rec, attn), llama-vision (attn×4, xattn)); parameters of
each pattern slot are stacked over superblocks and the stack is scanned —
keeping HLO size O(pattern) instead of O(num_layers), which is what makes the
512-device dry-run compiles tractable.  Layout:

    params = {embed, [head], [head_layers...], blocks: (slot -> stacked),
              [tail_layers...], final_norm}

``first_dense_layers`` (DeepSeek-V2) live in head_layers (explicit); a
non-divisible pattern remainder lives in tail_layers (explicit).

Two parameterizations share all apply code via the `lin` dispatcher:
  train/QAT : linear leaves {'w'} — STE ternary quant in forward.
  serve/RSR : linear leaves {'codes','scale'} — the paper's index, applied via
              repro.models.modules.rsr_linear_apply.
``serve_params`` converts a trained tree (offline, Algorithm 1 per matrix);
running it under jax.eval_shape yields the dry-run's abstract serve tree.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import modules as nn
from repro.models import moe as moe_lib
from repro.models import ssm


# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> list[str]:
    pat = cfg.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _use_moe(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.num_experts > 0 and layer_idx >= cfg.first_dense_layers


def _lin(cfg: ModelConfig, quantize: bool = True):
    """Parameterization-dispatching linear apply."""
    def apply(p, x):
        if "codes" in p:
            return nn.rsr_linear_apply(p, x, cfg=cfg)
        return nn.linear_apply(p, x, cfg=cfg, quantize=quantize)
    return apply


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str, use_moe: bool) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": nn.init_norm(cfg.d_model, cfg)}
    if kind == "attn":
        p["attn"] = (attn.init_mla(ks[0], cfg) if cfg.attention == "mla"
                     else attn.init_gqa(ks[0], cfg))
    elif kind == "xattn":
        p["attn"] = attn.init_cross(ks[0], cfg)
    elif kind == "rec":
        p["mixer"] = ssm.init_rglru(ks[0], cfg)
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba2(ks[0], cfg)
        return p                                   # mamba blocks: mixer only
    else:
        raise ValueError(kind)
    p["ln2"] = nn.init_norm(cfg.d_model, cfg)
    p["moe" if use_moe else "ffn"] = (
        moe_lib.init_moe(ks[1], cfg) if use_moe else nn.init_ffn(ks[1], cfg))
    return p


def apply_layer(p: dict, x: jax.Array, *, kind: str, cfg: ModelConfig,
                lin, image_embeds=None, cache: Optional[dict] = None,
                pos: Optional[jax.Array] = None, tables=None,
                paged_attn: str = "gather"):
    """Returns (x, aux_loss, new_cache).  ``tables`` is the paged-mode pair
    (full-attention table, ring table); attention layers pick theirs, SSM /
    cross-attention state is per-slot and ignores it.  ``paged_attn``
    selects the paged scoring backend (in-place Pallas ``kernel`` vs the
    dense-view ``gather`` reference; see repro.kernels.paged_attention)."""
    aux = jnp.zeros((), jnp.float32)
    h = nn.norm_apply(p["ln1"], x, cfg=cfg)
    new_cache = cache
    table_full, table_ring = tables if tables is not None else (None, None)
    if kind == "attn":
        window = cfg.window
        if cfg.attention == "mla":
            out, new_cache = attn.mla_apply(p["attn"], h, cfg=cfg, lin=lin,
                                            cache=cache, pos=pos,
                                            table=table_full,
                                            paged_backend=paged_attn)
        else:
            out, new_cache = attn.gqa_apply(
                p["attn"], h, cfg=cfg, lin=lin, window=window, cache=cache,
                pos=pos, table=table_ring if window > 0 else table_full,
                paged_backend=paged_attn)
    elif kind == "xattn":
        out, new_cache = attn.cross_apply(p["attn"], h, image_embeds, cfg=cfg,
                                          lin=lin, cache=cache)
    elif kind in ("rec", "mamba"):
        fn = ssm.rglru_apply if kind == "rec" else ssm.mamba2_apply
        out, new_cache = fn(p["mixer"], h, cfg=cfg, lin=lin, cache=cache,
                            pos=pos)
    else:
        raise ValueError(kind)
    x = x + out
    if kind == "mamba":
        return x, aux, new_cache

    h = nn.norm_apply(p["ln2"], x, cfg=cfg)
    if "moe" in p:
        if isinstance(p["moe"].get("wi"), dict):   # serve (RSR) parameterization
            out, aux = moe_lib.moe_apply_serve(p["moe"], h, cfg=cfg)
        else:
            out, aux = moe_lib.moe_apply(p["moe"], h, cfg=cfg, lin=lin)
    else:
        out = nn.ffn_apply(p["ffn"], h, cfg=cfg, apply_linear=lin)
    return x + out, aux, new_cache


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int, *,
                     abstract: bool = False, layout=None):
    if kind == "attn":
        if cfg.attention == "mla":
            return attn.init_mla_cache(cfg, batch, max_seq, abstract=abstract,
                                       layout=layout)
        return attn.init_gqa_cache(cfg, batch, max_seq, window=cfg.window,
                                   abstract=abstract, layout=layout)
    if kind == "xattn":
        return attn.init_cross_cache(cfg, batch, abstract=abstract)
    if kind == "rec":
        return ssm.init_rglru_cache(cfg, batch, abstract=abstract)
    if kind == "mamba":
        return ssm.init_mamba2_cache(cfg, batch, abstract=abstract)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def _layer_split(cfg: ModelConfig):
    """-> (head_kinds, pattern, n_super, tail_kinds)."""
    kinds = layer_kinds(cfg)
    nh = cfg.first_dense_layers
    head = kinds[:nh]
    rest = kinds[nh:]
    pat = cfg.block_pattern
    n_super = len(rest) // len(pat)
    tail = rest[n_super * len(pat):]
    return head, pat, n_super, tail


def init_params(cfg: ModelConfig, key) -> dict:
    head_kinds, pat, n_super, tail_kinds = _layer_split(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": nn.init_embed(keys[0], cfg),
                              "final_norm": nn.init_norm(cfg.d_model, cfg)}
    if not cfg.tie_embeddings:
        params["head"] = nn.init_linear(keys[1], cfg.d_model, cfg.vocab_size,
                                        cfg=cfg)
    params["head_layers"] = [
        init_layer(jax.random.fold_in(keys[2], i), cfg, kind, use_moe=False)
        for i, kind in enumerate(head_kinds)]
    blocks = {}
    for j, kind in enumerate(pat):
        lk = jax.random.split(jax.random.fold_in(keys[3], j), max(n_super, 1))
        um = _use_moe(cfg, cfg.first_dense_layers + j)
        blocks[f"slot{j}"] = jax.vmap(
            lambda k: init_layer(k, cfg, kind, use_moe=um))(lk[:n_super]) \
            if n_super > 0 else None
    params["blocks"] = {k: v for k, v in blocks.items() if v is not None}
    params["tail_layers"] = [
        init_layer(jax.random.fold_in(keys[4], i), cfg, kind,
                   use_moe=_use_moe(cfg, cfg.num_layers - len(tail_kinds) + i))
        for i, kind in enumerate(tail_kinds)]
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params: dict, batch: dict, cfg: ModelConfig, *,
            quantize: bool = True, remat: bool = False,
            return_hidden: bool = False) -> tuple:
    """-> (logits (B,S,V), aux_loss[, final hidden states (B,S,d)])."""
    lin = _lin(cfg, quantize)
    head_kinds, pat, n_super, tail_kinds = _layer_split(cfg)
    image_embeds = batch.get("image_embeds")

    if "embeds" in batch:                        # modality frontend stub
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = nn.embed_apply(params["embed"], batch["tokens"], cfg=cfg)

    aux = jnp.zeros((), jnp.float32)
    for p, kind in zip(params["head_layers"], head_kinds):
        x, a, _ = apply_layer(p, x, kind=kind, cfg=cfg, lin=lin,
                              image_embeds=image_embeds)
        aux = aux + a

    if n_super > 0:
        def superblock(carry, sb_params):
            x, aux = carry
            for j, kind in enumerate(pat):
                x, a, _ = apply_layer(sb_params[f"slot{j}"], x, kind=kind,
                                      cfg=cfg, lin=lin,
                                      image_embeds=image_embeds)
                aux = aux + a
            return (x, aux), None
        if remat:
            superblock = jax.checkpoint(superblock)
        (x, aux), _ = jax.lax.scan(superblock, (x, aux), params["blocks"])

    for p, kind in zip(params["tail_layers"], tail_kinds):
        x, a, _ = apply_layer(p, x, kind=kind, cfg=cfg, lin=lin,
                              image_embeds=image_embeds)
        aux = aux + a

    x = nn.norm_apply(params["final_norm"], x, cfg=cfg)
    logits = nn.head_apply(params["embed"], params.get("head"), x, cfg=cfg)
    if return_hidden:
        return logits.astype(jnp.float32), aux, x
    return logits.astype(jnp.float32), aux


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, *,
            quantize: bool = True, remat: bool = False,
            aux_weight: float = 0.01):
    """Vocab-parallel cross entropy.

    ``take_along_axis`` over the vocab dim of sharded logits makes GSPMD
    all-gather the FULL logits tensor (measured: 196 GiB/chip/step f32 on
    mamba2 train_4k, twice — fwd + bwd scatter-add; EXPERIMENTS §Perf).
    Megatron-style alternative:  nll = logsumexp(logits) − ⟨h, E[label]⟩ —
    logsumexp reduces the sharded vocab axis with a local reduce + tiny
    psum, and the label's output-embedding row is a table gather (one
    table-sized all-gather per step instead of a logits-sized one).
    """
    logits, aux, h = forward(params, batch, cfg, quantize=quantize,
                             remat=remat, return_hidden=True)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)        # (B,S)
    if cfg.tie_embeddings or "head" not in params:
        emb = jnp.take(params["embed"]["table"], labels, axis=0)
    else:                                                     # head.w (d,V)
        emb = jnp.moveaxis(jnp.take(params["head"]["w"], labels, axis=1),
                           0, -1)                             # (B,S,d)
    label_logit = jnp.einsum("bsd,bsd->bs", h.astype(jnp.float32),
                             emb.astype(jnp.float32))
    nll = lse - label_logit
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, (loss, aux)


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
               abstract: bool = False, layout=None) -> dict:
    """Dense mode (``layout is None``): per-slot rows, the PR-2 layout.

    Paged mode (``layout`` a ``repro.serve.paging.PagedLayout``): every
    attention layer's leaves become global block POOLS with a leading
    physical-block axis (``num_blocks + 1``; the last block is the idle-row
    trash sink) and the tree gains ``table (batch, mb_full + mb_ring)`` of
    physical ids, initialized to the trash block.  SSM / cross-attention
    leaves stay per-slot in both modes.
    """
    head_kinds, pat, n_super, tail_kinds = _layer_split(cfg)

    def mk(kind):
        return init_layer_cache(cfg, kind, batch, max_seq, abstract=abstract,
                                layout=layout)

    blocks = {}
    for j, kind in enumerate(pat):
        if n_super > 0:
            one = mk(kind)
            if abstract:
                blocks[f"slot{j}"] = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((n_super, *s.shape),
                                                   s.dtype), one)
            else:
                blocks[f"slot{j}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_super, *a.shape)).copy(),
                    one)
    pos = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
           else jnp.zeros((batch,), jnp.int32))
    out = {"head": [mk(k) for k in head_kinds],
           "blocks": blocks,
           "tail": [mk(k) for k in tail_kinds],
           "pos": pos}
    if layout is not None:
        tshape = (batch, layout.mb_total)
        out["table"] = (jax.ShapeDtypeStruct(tshape, jnp.int32) if abstract
                        else jnp.full(tshape, layout.trash_block, jnp.int32))
    return out


def prefill_step(params: dict, cache: dict, tokens: jax.Array,
                 cfg: ModelConfig, layout=None,
                 paged_attn: str = "gather") -> tuple:
    """Chunk of C ≥ 1 tokens per sequence against the live cache.

    tokens (B, C) -> (last-position logits (B, V), new cache); the per-slot
    positions ``cache['pos']`` advance by C.  C == 1 is exactly the decode
    step; C > 1 is the chunked-prefill hot path — every quantized linear
    flattens B·C rows, so the dispatcher leaves the decode tile regime and
    amortizes the one-hot build across the chunk.

    ``layout`` (a static ``repro.serve.paging.PagedLayout``) switches the
    KV side to the block-paged cache: the shared ``cache['table']`` is
    split into its full-attention and ring column ranges and handed DOWN
    TO THE ATTENTION LAYERS AS DEVICE ARRAYS — with ``paged_attn ==
    "kernel"`` (the default Engine resolution) the table reaches the
    Pallas paged-attention kernel as a scalar-prefetch operand whose
    values drive the KV block index maps, so attention runs in place over
    the pool; with ``"gather"`` the layers materialize the dense per-slot
    view first (the bitwise parity reference).  The layer math is
    otherwise identical, and the table passes through unchanged (block
    assignment is host-side engine work).
    """
    lin = _lin(cfg, quantize=False)
    head_kinds, pat, n_super, tail_kinds = _layer_split(cfg)
    pos = cache["pos"]
    tables = None
    if layout is not None:
        table = cache["table"]
        tables = (table[:, :layout.mb_full], table[:, layout.mb_full:])
    x = nn.embed_apply(params["embed"], tokens, cfg=cfg)

    new_head = []
    for p, kind, c in zip(params["head_layers"], head_kinds, cache["head"]):
        x, _, nc = apply_layer(p, x, kind=kind, cfg=cfg, lin=lin, cache=c,
                               pos=pos, tables=tables, paged_attn=paged_attn)
        new_head.append(nc)

    new_blocks = {}
    if n_super > 0:
        def superblock(x, scanned):
            sb_params, sb_cache = scanned
            new_c = {}
            for j, kind in enumerate(pat):
                x, _, nc = apply_layer(sb_params[f"slot{j}"], x, kind=kind,
                                       cfg=cfg, lin=lin,
                                       cache=sb_cache[f"slot{j}"], pos=pos,
                                       tables=tables, paged_attn=paged_attn)
                new_c[f"slot{j}"] = nc
            return x, new_c
        x, new_blocks = jax.lax.scan(superblock, x,
                                     (params["blocks"], cache["blocks"]))

    new_tail = []
    for p, kind, c in zip(params["tail_layers"], tail_kinds, cache["tail"]):
        x, _, nc = apply_layer(p, x, kind=kind, cfg=cfg, lin=lin, cache=c,
                               pos=pos, tables=tables, paged_attn=paged_attn)
        new_tail.append(nc)

    # only the chunk's last position feeds sampling (interior chunk logits
    # are never consumed), so the LM head projects a single row per slot
    x = nn.norm_apply(params["final_norm"], x[:, -1:], cfg=cfg)
    logits = nn.head_apply(params["embed"], params.get("head"), x, cfg=cfg)
    new_cache = {"head": new_head, "blocks": new_blocks, "tail": new_tail,
                 "pos": pos + tokens.shape[1]}
    if layout is not None:
        new_cache["table"] = cache["table"]
    return logits[:, 0].astype(jnp.float32), new_cache


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                cfg: ModelConfig) -> tuple:
    """One token per sequence. tokens (B, 1) -> (logits (B,V), new cache).

    The C == 1 case of :func:`prefill_step` (kept as the named decode entry
    point: the serving hot loop, dry-run compiles, and the scan-prefill
    reference all target it)."""
    return prefill_step(params, cache, tokens, cfg)


# ---------------------------------------------------------------------------
# Per-slot cache views (continuous batching: admit/evict one slot at a time)
# ---------------------------------------------------------------------------

# Leaf names that hold global block POOLS in paged mode (leading axis is
# physical blocks, not batch): attention k/v and the MLA latent pair.  All
# other cache leaves (SSM state, conv buffers, cross-attn kv) stay
# batch-leading in both modes.
_POOL_KEYS = {"k", "v", "c_kv", "k_pe"}


def _leaf_key(path) -> str:
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", ""))


def _is_pool(path) -> bool:
    return _leaf_key(path) in _POOL_KEYS


def slot_cache(cache: dict, i, *, paged: bool = False) -> dict:
    """Batch row ``i`` of a batched cache as a batch-1 cache.

    ``blocks`` leaves carry a leading superblock axis (stacked for the
    lax.scan), so their batch axis is 1; everything else is batch-leading.
    In paged mode the pool leaves are GLOBAL (shared by every slot) and
    pass through unsliced; the table row is sliced like ``pos``.
    """
    def sl(axis):
        def f(path, a):
            if paged and _is_pool(path):
                return a
            return jax.lax.dynamic_slice_in_dim(a, i, 1, axis=axis)
        return f
    tmap = jax.tree_util.tree_map_with_path
    out = {"head": tmap(sl(0), cache["head"]),
           "blocks": tmap(sl(1), cache["blocks"]),
           "tail": tmap(sl(0), cache["tail"]),
           "pos": jax.lax.dynamic_slice_in_dim(cache["pos"], i, 1, axis=0)}
    if paged:
        out["table"] = jax.lax.dynamic_slice_in_dim(cache["table"], i, 1,
                                                    axis=0)
    return out


def update_slot_cache(cache: dict, sub: dict, i, *, paged: bool = False
                      ) -> dict:
    """Write a batch-1 cache ``sub`` into row ``i`` of a batched cache.

    In paged mode the pool leaves are adopted from ``sub`` WHOLESALE: the
    batch-1 run wrote its blocks into the same global pool, so ``sub``'s
    version is the newest (every other slot's blocks are untouched rows of
    the same arrays)."""
    def up(axis):
        def f(path, a, s):
            if paged and _is_pool(path):
                return s.astype(a.dtype)
            return jax.lax.dynamic_update_slice_in_dim(
                a, s.astype(a.dtype), i, axis=axis)
        return f
    tmap = jax.tree_util.tree_map_with_path
    out = {"head": tmap(up(0), cache["head"], sub["head"]),
           "blocks": tmap(up(1), cache["blocks"], sub["blocks"]),
           "tail": tmap(up(0), cache["tail"], sub["tail"]),
           "pos": jax.lax.dynamic_update_slice_in_dim(
               cache["pos"], sub["pos"].astype(cache["pos"].dtype), i,
               axis=0)}
    if paged:
        out["table"] = jax.lax.dynamic_update_slice_in_dim(
            cache["table"], sub["table"].astype(cache["table"].dtype), i,
            axis=0)
    return out


def adopt_pools(per_slot_src: dict, pool_src: dict) -> dict:
    """Paged helper: take every per-slot leaf (and table/pos) from
    ``per_slot_src`` and every pool leaf from ``pool_src``.  Used to build
    a fresh batch-1 admission state that writes into the LIVE global pool
    (the per-slot template's own dummy pools are discarded)."""
    tmap = jax.tree_util.tree_map_with_path

    def pick(path, a, b):
        return b if _is_pool(path) else a

    out = {key: tmap(pick, per_slot_src[key], pool_src[key])
           for key in ("head", "blocks", "tail")}
    out["pos"] = per_slot_src["pos"]
    out["table"] = per_slot_src["table"]
    return out


def copy_pool_block(cache: dict, src, dst) -> dict:
    """Copy physical block ``src`` -> ``dst`` in EVERY pool leaf (the
    device half of copy-on-write; allocator bookkeeping is host-side in
    ``repro.serve.paging.BlockPool.ensure_exclusive``).  ``blocks`` leaves
    carry the stacked superblock axis first, so their block axis is 1."""
    def cp(axis):
        def f(path, a):
            if not _is_pool(path):
                return a
            blk = jax.lax.dynamic_slice_in_dim(a, src, 1, axis=axis)
            return jax.lax.dynamic_update_slice_in_dim(a, blk, dst,
                                                       axis=axis)
        return f
    tmap = jax.tree_util.tree_map_with_path
    out = dict(cache)
    out["head"] = tmap(cp(0), cache["head"])
    out["blocks"] = tmap(cp(1), cache["blocks"])
    out["tail"] = tmap(cp(0), cache["tail"])
    return out


# ---------------------------------------------------------------------------
# Serve parameterization (offline conversion; abstract via eval_shape)
# ---------------------------------------------------------------------------

_NO_QUANT_KEYS = {"embed", "head", "router", "kv_norm", "ln1", "ln2", "norm",
                  "final_norm"}
# MLA up-projections are consumed per-head inside the absorbed-decode einsums
# (q·W_UK, o·W_UV) rather than as vector-matrix products, so the RSR index
# does not apply to them at serve time; they serve as dense dequant (γ·W_t).
# See DESIGN.md §4 (arch-applicability).
_DEQUANT_ONLY_KEYS = {"w_uk", "w_uv"}


def _vmap_leading(fn, arr, ndim_base: int):
    if arr.ndim == ndim_base:
        return fn(arr)
    return jax.vmap(lambda a: _vmap_leading(fn, a, ndim_base))(arr)


def serve_params(params: dict, cfg: ModelConfig) -> dict:
    """Trained tree -> RSR serve tree (Algorithm 1 offline, per matrix)."""
    from repro.core.preprocess import preprocess_ternary_direct
    from repro.core.ternary import absmean_quantize

    def dequant(p):                               # dense-serve baseline:
        def one(w):                               # serve γ·W_t as plain bf16
            wt, gamma = absmean_quantize(w.astype(jnp.float32))
            return (gamma * wt).astype(jnp.dtype(cfg.dtype))
        out = {"w": _vmap_leading(one, p["w"], 2)}
        if "b" in p:
            out["b"] = p["b"]
        return out

    def conv_linear(p):                           # {'w'[,b]} possibly stacked
        from repro.models.modules import serve_linear_params

        # the vmapped conversion carries the whole serve dict — codes, the
        # word-packed kernel stream, scale, and the zero-size n_out shape
        # marker (which must gain the stacked leading dims like every other
        # leaf so lax.scan over superblocks slices it consistently)
        out = _vmap_leading(lambda w: serve_linear_params({"w": w}, cfg=cfg),
                            p["w"], 2)
        if "b" in p:
            out["b"] = p["b"].astype(jnp.float32)
        return out

    def conv_bank(bank):                          # raw (..., n, m) expert bank
        from repro.models.modules import serve_linear_params
        return _vmap_leading(
            lambda w: serve_linear_params({"w": w}, cfg=cfg), bank, 2)

    def walk(node, name: str):
        if isinstance(node, dict):
            if name in _NO_QUANT_KEYS:
                return node
            if "w" in node and name not in _NO_QUANT_KEYS:
                if cfg.quant == "none":
                    return node
                if name in _DEQUANT_ONLY_KEYS:
                    return dequant(node)
                return conv_linear(node) if cfg.rsr_serve else dequant(node)
            if "router" in node:                  # moe dict
                out = {"router": node["router"]}
                for nm in ("wi", "wg", "wo"):
                    out[nm] = conv_bank(node[nm]) if cfg.rsr_serve \
                        else node[nm]
                if "shared" in node:
                    out["shared"] = {k2: walk(v2, k2)
                                     for k2, v2 in node["shared"].items()}
                return out
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, name) for v in node]
        return node

    return walk(params, "")
