"""Subsystem package."""
