"""Sharding rules: path-pattern -> PartitionSpec over the production mesh.

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single-pod.

Parallelism mapping (DESIGN.md §3):
  DP    batch on ("pod","data")
  FSDP  weight dim-0 on "data" (ZeRO-3-style; XLA inserts the all-gathers,
        optimizer states inherit the shard => ZeRO-1 for free)
  TP    attention heads / FFN inner / vocab on "model"
  EP    MoE expert axis on "model"
  SP    sequence dim of long activations on "model" between attention blocks
        (applied via activation constraints in the step functions)
RSR serve indices shard like the weights they replace: the block axis (nb,
which tiles the output features) goes on "model".

Rules are (regex over the '/'-joined param path, spec for the *base* rank);
stacked scan leaves (extra leading layer axis) are handled by left-padding the
spec with None.  An axis is applied only if it divides the dim size —
otherwise that dim falls back to replication (e.g. MQA kv=1 heads).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "shardings",
           "dp_axes", "logical_rules", "constrain"]


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Rule table: (path regex, base spec).  "dp"/"fsdp"/"tp" are placeholders
# resolved against the mesh.  First match wins.
# ---------------------------------------------------------------------------

def logical_rules() -> list[tuple[str, tuple]]:
    return [
        # embeddings / head: vocab on "model" ONLY — an FSDP factor on the
        # d dim makes the head matmul contract over a sharded axis, and XLA
        # partial-matmuls + ALL-REDUCES the full (B,S,V) logits (measured
        # 192 GiB/chip-step on granite train) instead of gathering the
        # ~150 MiB table (EXPERIMENTS §Perf iter 8).
        (r"embed/table$",            ("tp", None)),
        (r"head/w$",                 (None, "tp")),
        # norms & scalars
        (r"(ln1|ln2|norm|final_norm|kv_norm)/(scale|bias)$", (None,)),
        (r"gate$",                   ()),
        # attention (gqa / mla / cross)
        (r"attn/w[qkv]/w$",          ("fsdp", "tp")),
        (r"attn/w[qkv]/b$",          ("tp",)),
        (r"attn/wo/w$",              ("tp", "fsdp")),
        (r"attn/wo/b$",              (None,)),
        (r"attn/w_dkv/w$",           ("fsdp", None)),
        (r"attn/w_kpe/w$",           ("fsdp", None)),
        (r"attn/w_u[kv]/w$",         (None, "tp")),
        (r"attn/w_u[kv]/(perm|seg)$", ("tp", None)),
        # dense FFN
        (r"ffn/w[ig]/w$",            ("fsdp", "tp")),
        (r"ffn/wo/w$",               ("tp", "fsdp")),
        (r"ffn/w[igo]/b$",           (None,)),
        # MoE (EP on model)
        (r"moe/router/w$",           (None, None)),
        (r"moe/w[ig]$",              ("tp", "fsdp", None)),
        (r"moe/wo$",                 ("tp", None, "fsdp")),
        (r"moe/w[igo]/(perm|seg)$",  ("tp", None, None)),
        (r"moe/w[igo]/scale$",       ("tp",)),
        (r"moe/shared/w[igo]/w$",    ("fsdp", "tp")),
        # mamba2
        (r"mixer/(z|x|b|c|dt)_proj/w$", ("fsdp", "tp")),
        (r"mixer/out_proj/w$",       ("tp", "fsdp")),
        (r"mixer/conv_w[xbc]$",      (None, "tp")),
        (r"mixer/conv_b(x|b|c2)$",   ("tp",)),
        (r"mixer/(A_log|D|dt_bias|lam)$", (None,)),
        # rg-lru
        (r"mixer/(wx|wgate)/w$",     ("fsdp", "tp")),
        (r"mixer/w_[ai]/w$",         ("tp", "fsdp")),
        (r"mixer/out/w$",            ("tp", "fsdp")),
        # RSR serve leaves (σ/L block axis tiles the output features)
        (r"(perm|seg|codes)$",       ("tp", None)),
        (r"scale$",                  ()),
        (r"/b$",                     ("tp",)),
        # fallback: replicate
        (r".*",                      None),
    ]


def _resolve_axis(tag, mesh: Mesh):
    if tag is None:
        return None
    if tag == "tp":
        return "model" if "model" in mesh.axis_names else None
    if tag == "fsdp":
        return "data" if "data" in mesh.axis_names else None
    if tag == "dp":
        return dp_axes(mesh)
    return tag


def _axis_size(ax, mesh: Mesh) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def _fit_spec(base: tuple, shape: tuple, mesh: Mesh) -> P:
    """Left-pad to rank, resolve placeholders, drop non-dividing axes."""
    base = tuple(base)
    if len(base) < len(shape):
        base = (None,) * (len(shape) - len(base)) + base
    base = base[-len(shape):] if len(base) > len(shape) else base
    out = []
    for dim, tag in zip(shape, base):
        ax = _resolve_axis(tag, mesh)
        if ax is not None and dim % _axis_size(ax, mesh) == 0 and dim > 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


SERVE_REPLICATE_BYTES = 4 * 2 ** 20   # replicate serve leaves under 4 MiB


def param_pspecs(params_abstract, mesh: Mesh, *, serve: bool = False,
                 replicate_small: bool = True):
    """Abstract param tree -> PartitionSpec tree (path-rule matching).

    serve=True applies the decode policy:
      * drop the FSDP ("data") factor — no optimizer state to shard, and
        FSDP all-gathers dominate the tiny step (125 MiB/step on the lm head
        alone, perf_iterations/iter2);
      * replicate small leaves (< SERVE_REPLICATE_BYTES): sharding a 1 MiB
        gate matrix buys nothing and costs a psum per layer per step
        (recurrentgemma decode was collective-dominated through its RG-LRU
        gate psums, perf_iterations/iter3).
    """
    rules = [(re.compile(rx), spec) for rx, spec in logical_rules()]

    def one(path, leaf):
        ps = _path_str(path)
        if serve:
            nbytes = int(np.prod(leaf.shape)) * jax.numpy.dtype(
                leaf.dtype).itemsize
            # codes are exempt: sharding the RSR block axis is what
            # parallelizes the segmented-sum scatter — replicated codes make
            # XLA split the scatter over the contraction dim instead, which
            # costs an all-reduce of u per linear (perf_iterations/iter4:
            # 26.6 MiB f32 AR per layer on recurrentgemma decode) AND
            # un-shards the 4 B/elem scatter-updates traffic.
            # batch-dependent policy: replicating a small weight trades
            # 16x its read traffic for removing a per-layer psum — a win at
            # batch >= ~16 (rgemma decode_32k), a 2x net LOSS at B=1
            # long-context decode (mamba long_500k, perf_iterations log).
            small = nbytes < SERVE_REPLICATE_BYTES
            is_index = ps.endswith(("codes", "perm", "seg"))
            if replicate_small and small and not is_index:
                return P()
        for rx, spec in rules:
            if rx.search(ps):
                if spec is None:
                    return P()
                use = spec
                if serve:
                    use = tuple(None if t == "fsdp" else t for t in spec)
                return _fit_spec(use, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(one, params_abstract)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_pspecs(batch_abstract, mesh: Mesh, *, seq_shard: bool = False):
    """Inputs: batch dim on DP axes; optional sequence sharding (SP)."""
    dp = dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None

    def one(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 1 and shape[0] % _axis_size(dp, mesh) == 0:
            spec[0] = dp
        if seq_shard and len(shape) >= 2 and tp and shape[1] % \
                _axis_size(tp, mesh) == 0:
            spec[1] = tp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, batch_abstract)


def cache_pspecs(cache_abstract, mesh: Mesh):
    """Decode-state sharding.

    KV-type caches (k/v/c_kv/k_pe/xk/xv) shard the SEQUENCE dim on "model":
    heads rarely divide tp (GQA kv=8 < 16, MQA kv=1), and head-dim sharding
    forces involuntary resharding copies of the whole cache every step
    (measured: 35× cache re-read, perf_iterations/iter0).  Seq-sharding turns
    decode attention into partial-softmax shards + two tiny all-reduces.
    Recurrent states shard their feature axis on "model"; batch on DP
    everywhere it divides.
    """
    dp = dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    dpsz = _axis_size(dp, mesh)
    tpsz = _axis_size(tp, mesh) if tp else 1

    KV_BHSD = {"k", "v"}             # (B, KVH, S, hd): seq at bdim+2
    KV_BSD = {"c_kv", "k_pe", "xk", "xv"}   # (B, S, ...): seq at bdim+1
    FEAT_NAMES = {"state", "h", "conv", "conv_x", "conv_b", "conv_c"}

    def one(path, leaf):
        ps = _path_str(path)
        name = ps.rsplit("/", 1)[-1]
        shape = leaf.shape
        spec = [None] * len(shape)
        off = 1 if "blocks" in ps and len(shape) >= 2 else 0
        bdim = off
        if len(shape) > bdim and shape[bdim] % dpsz == 0 and shape[bdim] > 1:
            spec[bdim] = dp
        if tp:
            seq_ax = None
            if name in KV_BHSD and len(shape) >= bdim + 3:
                # prefer head sharding when kvh divides tp; else shard seq
                if shape[bdim + 1] % tpsz == 0 and shape[bdim + 1] >= tpsz:
                    spec[bdim + 1] = tp
                else:
                    seq_ax = bdim + 2
            elif name in KV_BSD and len(shape) >= bdim + 2:
                seq_ax = bdim + 1
            if seq_ax is not None and shape[seq_ax] % tpsz == 0 and \
                    shape[seq_ax] >= tpsz:
                spec[seq_ax] = tp                     # sequence dim
            if name in FEAT_NAMES:
                for cand in range(bdim + 1, len(shape)):
                    if shape[cand] % tpsz == 0 and shape[cand] >= tpsz:
                        spec[cand] = tp               # feature/heads dim
                        break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def shardings(tree_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
