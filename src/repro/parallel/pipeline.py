"""Experimental GPipe-style pipeline parallelism over the "pod" axis.

The production dry-run maps "pod" to data parallelism (DESIGN.md §3); this
module provides the alternative: split the layer stack into one stage per
pod and stream microbatches through a shard_map ppermute ring.

Schedule (GPipe, fill-drain): with S stages and M microbatches, step t ∈
[0, S+M-1) has stage s processing microbatch (t - s); activations hop
stage→stage via collective-permute each step.  Bubble fraction =
(S-1)/(S+M-1) — the classic trade documented for operators choosing between
pod-DP (no bubble, gradient all-reduce over ICI/DCN) and pod-PP (bubble,
point-to-point activations only).

`pipeline_apply` is deliberately minimal — layer_fn is any
(stage_params, x) -> x; correctness is tested against the sequential stack
on an 8-device mesh (tests/test_parallel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_params, x: jax.Array, layer_fn, mesh: Mesh, *,
                   axis: str = "pod", microbatches: int = 4) -> jax.Array:
    """y = stage_S(...stage_1(x)) with stages sharded over ``axis``.

    stage_params: pytree whose leaves have leading dim = n_stages (stacked
    per-stage parameters; stage s uses leaf[s]).
    x: (B, ...) global batch; B must divide by microbatches.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % microbatches == 0, (b, microbatches)
    mb = b // microbatches

    def body(params_local, x_local):
        # params_local: this stage's params (leading dim 1) ; x_local: full
        # batch slice replicated — each stage computes only its microbatch.
        params_me = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        xs = x_local.reshape(microbatches, mb, *x_local.shape[1:])

        n_ticks = n_stages + microbatches - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            m_idx = t - sid                       # microbatch at this stage
            active = (m_idx >= 0) & (m_idx < microbatches)
            # stage 0 injects fresh microbatches; others take the ring input
            inject = xs[jnp.clip(m_idx, 0, microbatches - 1)]
            cur = jnp.where(sid == 0, inject, buf)
            y = layer_fn(params_me, cur)
            y = jnp.where(active, y, buf)
            # last stage records output; others forward along the ring
            outs = jax.lax.cond(
                active & (sid == n_stages - 1),
                lambda o: o.at[jnp.clip(m_idx, 0, microbatches - 1)].set(y),
                lambda o: o, outs)
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them back
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(b, *x.shape[1:])

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),      # stage-stacked params sharded on axis
        out_specs=P(),
        check_rep=False)(stage_params, x)


def bubble_fraction(n_stages: int, microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + microbatches - 1)
