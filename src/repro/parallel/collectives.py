"""Distributed-optimization collectives.

* ``ef_compress_tree``        — int8 stochastic-free deterministic gradient
                                quantization with error feedback (persistent
                                residual closes the compression error over
                                steps).  Applied pre-update; with FSDP grads
                                the quantize→dequantize pair bounds the
                                reduce-scatter payload to 1 byte/element.
* ``compressed_psum``         — shard_map building block: quantize local
                                grads to int8, psum the int8 payload + scales,
                                dequantize (4× all-reduce traffic reduction).
* ``collective_matmul``       — shard_map all-gather-overlap matmul
                                (bidirectional ppermute ring): each step
                                matmuls the resident shard while the next
                                shard is in flight — the standard TP
                                compute/comm overlap pattern, exposed for the
                                hillclimb experiments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

# persistent error-feedback residuals keyed by tree structure (host-side)
_EF_STATE: dict = {}


def _quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress_tree(grads, state_key: str = "default"):
    """Quantize each grad leaf to int8+scale and dequantize, carrying the
    quantization error into the next step (error feedback)."""
    residual = _EF_STATE.get(state_key)
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = _quantize_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq

    out = jax.tree.map(one, grads, residual)
    two = lambda x: isinstance(x, tuple) and len(x) == 2
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=two)
    _EF_STATE[state_key] = jax.tree.map(lambda o: o[1], out, is_leaf=two)
    return deq


def compressed_psum(x: jax.Array, axis_name: str):
    """int8-payload all-reduce with exact per-shard scales (in shard_map).

    Wire carries the int8 tensors (1 B/elem, 4× less than f32) plus one
    scalar scale per shard; the weighted sum happens after dequant on each
    receiver — the standard compressed all-reduce semantics."""
    q, scale = _quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # (n_shards, ...) int8 wire
    ss = jax.lax.all_gather(scale, axis_name)      # (n_shards,) scalars
    shape = (-1,) + (1,) * q.ndim
    return jnp.sum(qs.astype(jnp.float32) * ss.reshape(shape), axis=0)


def compressed_psum_exact(x: jax.Array, axis_name: str):
    """int8 payload all-reduce preserving per-shard scales exactly:
    all-gather scales (tiny), psum int8 per-shard weighted.  Traffic:
    1 byte/elem + |axis| scalars."""
    q, scale = _quantize_int8(x)
    contrib = q.astype(jnp.float32) * scale
    return jax.lax.psum(contrib, axis_name)   # reference semantics


def collective_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                      axis: str = "model"):
    """y = x @ w with w column-sharded on ``axis`` and x row-resident:
    ring all-gather of x overlapped with per-shard matmuls.

    x (B, K) replicated on axis; w (K, N) with N sharded.  Demonstration of
    the overlap schedule (the dry-run HLO shows collective-permute chains
    instead of a blocking all-gather)."""
    n_shards = mesh.shape[axis]

    def body(x_loc, w_loc):
        # x_loc: (B, K/n) — this shard's slice; w_loc: (K, N/n)
        idx = jax.lax.axis_index(axis)
        k_loc = x_loc.shape[-1]
        acc = jnp.zeros((x_loc.shape[0], w_loc.shape[1]), jnp.float32)
        # carry varies over the ring axis; pvary only exists (and is only
        # required by shard_map's varying-axes check) on newer jax
        pvary = getattr(jax.lax, "pvary", None)
        if pvary is not None:
            acc = pvary(acc, (axis,))
        chunk = x_loc

        def step(i, carry):
            acc, chunk = carry
            src = (idx - i) % n_shards              # whose slice we now hold
            w_slice = jax.lax.dynamic_slice_in_dim(
                w_loc, src * k_loc, k_loc, axis=0)
            acc = acc + chunk.astype(jnp.float32) @ w_slice.astype(jnp.float32)
            chunk = jax.lax.ppermute(
                chunk, axis,
                [(j, (j + 1) % n_shards) for j in range(n_shards)])
            return acc, chunk

        acc, _ = jax.lax.fori_loop(0, n_shards, step, (acc, chunk))
        return acc.astype(x.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis)),
        out_specs=P(None, axis))(x, w)
