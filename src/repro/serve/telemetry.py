"""Serve-plane telemetry: metrics registry, request tracing, profiling.

Nine PRs of serve plane (priority scheduling, chaos hardening, durable
checkpoints) grew four independently-invented ``stats`` dict idioms
(``frontend.py``, ``paging.py``, ``durability.py``, ``faults.py``) and a
hardcoded ``time.perf_counter()`` pair in ``engine.py`` — scattered
enough that "where did this request's latency go?" had no answer.  This
module is the one measurement substrate under all of it:

* **Metrics registry** — typed :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` families with label dimensions, rendered as
  Prometheus text exposition or a JSON snapshot.  When telemetry is
  disabled (the default), every registry constructor hands back the one
  shared :data:`NULL` no-op metric: call sites pay a single attribute
  call and allocate nothing.
* **Dict-compatible counter views** — :func:`stats_counters` returns a
  :class:`StatsView`, a ``MutableMapping`` that the legacy ``stats``
  dict call sites (``stats["k"] += 1``, ``dict(stats)``,
  ``stats == {...}``) drive unchanged, while the registry ``adopt()``-s
  it as a labelled counter family for export.  Views count ALWAYS —
  tests and benches assert on them with telemetry off; the enabled flag
  gates only the extra work (tracing, phase timers, histograms,
  gauges).
* **Request-lifecycle tracing** — :class:`Tracer` records schema'd
  events (``submit → admit → first_token → … → finish``, see the
  catalog in ``repro/serve/__init__.py``) with a monotonically
  increasing ``seq`` ordinal and timestamps the *caller* reads from the
  scheduler's injectable clock — never a wall clock of this module's
  own — so a fake/fault clock makes the export byte-deterministic.
* **Kernel profiling hooks** — :func:`record_dispatch` /
  :func:`observe_dispatch_seconds` count RSR serve-matmul dispatches by
  backend/regime/tile and time autotune candidates.  These live at
  module scope (dispatch has no engine handle) and fire once per traced
  shape, so they are unconditionally on.

Enablement resolves the repo-wide precedence rule: ``$REPRO_TELEMETRY``
outranks ``ServeConfig.telemetry``; ``$REPRO_TRACE_PATH`` outranks
``ServeConfig.trace_path`` (a configured path makes ``dump_trace()``
also write the JSON there).

The module imports only the stdlib — every serve module (and
``kernels/dispatch.py``) can import it without cycles.
"""
from __future__ import annotations

import json
import os
from collections.abc import MutableMapping
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
    "Telemetry", "Tracer", "latency_attribution", "observe_dispatch_seconds",
    "record_dispatch", "stats_counters",
]

# fixed histogram buckets (seconds): 100us .. 10s geometric-ish ladder,
# +Inf implicit.  Fixed at module scope so two runs of the same traffic
# always land counts in the same buckets — exports stay comparable.
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _truthy(v: str) -> bool:
    return v.strip().lower() in ("1", "true", "yes", "on")


def _fmt(v) -> str:
    """Deterministic sample formatting: integral floats print as ints."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def _label_str(names: Tuple[str, ...], values: Tuple[str, ...]) -> str:
    if not names:
        return ""
    inner = ",".join(f'{n}="{v}"' for n, v in zip(names, values))
    return "{%s}" % inner


class _NullMetric:
    """The shared disabled-mode metric: every mutator is a no-op and
    ``labels()`` returns itself, so a disabled call chain touches no
    allocation at all."""
    __slots__ = ()

    def inc(self, amount: float = 1) -> None:
        pass

    def dec(self, amount: float = 1) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def labels(self, **kv) -> "_NullMetric":
        return self


NULL = _NullMetric()


class _Bound:
    """One labelled child of a Counter/Gauge family."""
    __slots__ = ("_family", "_key")

    def __init__(self, family, key: Tuple[str, ...]):
        self._family = family
        self._key = key

    def inc(self, amount: float = 1) -> None:
        s = self._family._samples
        s[self._key] = s.get(self._key, 0) + amount

    def dec(self, amount: float = 1) -> None:
        self.inc(-amount)

    def set(self, value: float) -> None:
        self._family._samples[self._key] = value


class _Family:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._samples: Dict[Tuple[str, ...], float] = {}

    def _resolve(self, kv: dict) -> Tuple[str, ...]:
        if tuple(sorted(kv)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(kv[n]) for n in self.labelnames)

    def labels(self, **kv) -> _Bound:
        return _Bound(self, self._resolve(kv))

    # zero-label convenience: the family itself acts as its () child
    def inc(self, amount: float = 1) -> None:
        self._samples[()] = self._samples.get((), 0) + amount

    def dec(self, amount: float = 1) -> None:
        self.inc(-amount)

    def set(self, value: float) -> None:
        self._samples[()] = value

    def value(self, **kv) -> float:
        key = self._resolve(kv) if kv else ()
        return self._samples.get(key, 0)

    def samples(self) -> List[Tuple[Tuple[str, ...], float]]:
        return sorted(self._samples.items())

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key, v in self.samples():
            lines.append(
                f"{self.name}{_label_str(self.labelnames, key)} {_fmt(v)}")
        return lines

    def to_json(self) -> dict:
        return {"type": self.kind, "help": self.help,
                "samples": [{"labels": dict(zip(self.labelnames, key)),
                             "value": v} for key, v in self.samples()]}


class Counter(_Family):
    kind = "counter"


class Gauge(_Family):
    kind = "gauge"


class _BoundHistogram:
    __slots__ = ("_family", "_key")

    def __init__(self, family, key: Tuple[str, ...]):
        self._family = family
        self._key = key

    def observe(self, value: float) -> None:
        self._family._observe(self._key, value)


class Histogram:
    """Fixed-bucket histogram family (cumulative ``le`` buckets, +Inf
    implicit).  Buckets are fixed at construction, so same-traffic runs
    export identical text."""
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = (),
                 buckets: Tuple[float, ...] = LATENCY_BUCKETS_S):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(sorted(buckets))
        # key -> [per-bucket counts..., +Inf count, sum]
        self._samples: Dict[Tuple[str, ...], List[float]] = {}

    def _resolve(self, kv: dict) -> Tuple[str, ...]:
        if tuple(sorted(kv)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(kv[n]) for n in self.labelnames)

    def labels(self, **kv) -> _BoundHistogram:
        return _BoundHistogram(self, self._resolve(kv))

    def observe(self, value: float) -> None:
        self._observe((), value)

    def _observe(self, key: Tuple[str, ...], value: float) -> None:
        row = self._samples.get(key)
        if row is None:
            row = self._samples[key] = [0.0] * (len(self.buckets) + 2)
        for i, b in enumerate(self.buckets):
            if value <= b:
                row[i] += 1
        row[-2] += 1                     # +Inf
        row[-1] += value                 # sum

    def count(self, **kv) -> float:
        key = self._resolve(kv) if kv else ()
        row = self._samples.get(key)
        return 0 if row is None else row[-2]

    def samples(self) -> List[Tuple[Tuple[str, ...], List[float]]]:
        return sorted(self._samples.items())

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        for key, row in self.samples():
            for i, b in enumerate(self.buckets):
                ls = _label_str(self.labelnames + ("le",),
                                key + (_fmt(b),))
                lines.append(f"{self.name}_bucket{ls} {_fmt(row[i])}")
            ls = _label_str(self.labelnames + ("le",), key + ("+Inf",))
            lines.append(f"{self.name}_bucket{ls} {_fmt(row[-2])}")
            base = _label_str(self.labelnames, key)
            lines.append(f"{self.name}_sum{base} {_fmt(row[-1])}")
            lines.append(f"{self.name}_count{base} {_fmt(row[-2])}")
        return lines

    def to_json(self) -> dict:
        return {"type": "histogram", "help": self.help,
                "buckets": list(self.buckets),
                "samples": [{"labels": dict(zip(self.labelnames, key)),
                             "counts": row[:-1], "sum": row[-1]}
                            for key, row in self.samples()]}


class StatsView(MutableMapping):
    """A counter family that walks and talks like the legacy ``stats``
    dict (``view["k"] += 1``, ``dict(view)``, ``view == {...}``,
    ``repr`` prints the dict) while exporting as one labelled family
    ``name{key="..."}``.  Counts unconditionally — the serve tests and
    benches assert these with telemetry disabled."""
    kind = "counter"
    labelnames = ("key",)

    def __init__(self, name: str, keys: Iterable[str] = (), help: str = ""):
        self.name = name
        self.help = help
        self._d: Dict[str, float] = {k: 0 for k in keys}

    # -- mapping surface ----------------------------------------------------

    def __getitem__(self, k):
        return self._d[k]

    def __setitem__(self, k, v):
        self._d[k] = v

    def __delitem__(self, k):
        del self._d[k]

    def __iter__(self):
        return iter(self._d)

    def __len__(self):
        return len(self._d)

    def __eq__(self, other):
        if isinstance(other, StatsView):
            return self._d == other._d
        if isinstance(other, dict):
            return self._d == other
        return NotImplemented

    def __ne__(self, other):
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    __hash__ = None

    def __repr__(self):
        return repr(self._d)

    # -- export surface -----------------------------------------------------

    def samples(self) -> List[Tuple[Tuple[str, ...], float]]:
        return sorted(((str(k),), v) for k, v in self._d.items())

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} counter"]
        for key, v in self.samples():
            lines.append(
                f"{self.name}{_label_str(self.labelnames, key)} {_fmt(v)}")
        return lines

    def to_json(self) -> dict:
        return {"type": "counter", "help": self.help,
                "samples": [{"labels": {"key": key[0]}, "value": v}
                            for key, v in self.samples()]}


def stats_counters(name: str, keys: Iterable[str] = (),
                   help: str = "") -> StatsView:
    """Standalone dict-compatible counter family (see
    :class:`StatsView`).  Module-level so objects constructed before any
    registry exists (``FaultPlan``, ``BlockPool``, ``CheckpointStore``)
    can count from birth; the scheduler's :class:`Telemetry` later
    ``adopt()``-s the instance for export."""
    return StatsView(name, keys, help)


class MetricsRegistry:
    """Name-keyed family store.  ``counter()/gauge()/histogram()`` are
    get-or-create by name; when the registry is disabled they return the
    shared :data:`NULL` metric and register nothing.  ``adopt()`` wires
    an externally-built family (``StatsView`` or module-level kernel
    counters) into the export regardless of the enabled flag — those
    count always and export whenever somebody asks."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._families: Dict[str, object] = {}

    def _get(self, name: str, cls, help: str, labels, **kw):
        if not self.enabled:
            return NULL
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = cls(name, help, labels, **kw)
        elif not isinstance(fam, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(fam).__name__}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()):
        return self._get(name, Counter, help, labels)

    def gauge(self, name: str, help: str = "", labels: Iterable[str] = ()):
        return self._get(name, Gauge, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (),
                  buckets: Tuple[float, ...] = LATENCY_BUCKETS_S):
        return self._get(name, Histogram, help, labels, buckets=buckets)

    def adopt(self, family):
        """Register a pre-built family/view under its own name (latest
        wins — a restored scheduler re-adopts its views)."""
        self._families[family.name] = family
        return family

    def render_prometheus(self) -> str:
        lines: List[str] = []
        for name in sorted(self._families):
            lines.extend(self._families[name].render())
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> dict:
        return {name: self._families[name].to_json()
                for name in sorted(self._families)}


class Tracer:
    """Append-only request-lifecycle event log.

    Events are plain dicts ``{"seq", "ev", "t", ...fields}``: ``seq`` is
    a 1-based ordinal (total order even when a fake clock repeats
    timestamps), ``t`` is the caller-supplied clock reading.  The JSON
    export is canonical (sorted keys, fixed separators), so two runs
    that generate the same events from the same injected clock export
    byte-identical bytes — the chaos-soak determinism contract."""

    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self.events: List[dict] = []
        self._seq = 0

    def event(self, ev: str, t: float, **fields) -> None:
        if not self.enabled:
            return
        self._seq += 1
        e = {"seq": self._seq, "ev": ev, "t": float(t)}
        e.update(fields)
        self.events.append(e)

    def clear(self) -> None:
        self.events = []
        self._seq = 0

    def export_json(self) -> str:
        return json.dumps({"schema": "repro_trace_v1",
                           "events": self.events},
                          sort_keys=True, separators=(",", ":"))


class Telemetry:
    """The per-engine telemetry plane: one registry + one tracer + the
    enablement/trace-path policy.  ``$REPRO_TELEMETRY`` outranks
    ``ServeConfig.telemetry``; ``$REPRO_TRACE_PATH`` outranks
    ``ServeConfig.trace_path``."""

    def __init__(self, enabled: bool = False,
                 trace_path: Optional[str] = None):
        self.enabled = bool(enabled)
        self.trace_path = trace_path or None
        self.registry = MetricsRegistry(enabled=self.enabled)
        self.trace = Tracer(enabled=self.enabled)
        # kernel-side module counters export through every telemetry
        # instance (dispatch has no engine handle to register with)
        self.registry.adopt(_DISPATCH_CALLS)
        self.registry.adopt(_DISPATCH_SECONDS)

    @classmethod
    def from_config(cls, scfg) -> "Telemetry":
        env = os.environ.get("REPRO_TELEMETRY")
        enabled = (_truthy(env) if env is not None
                   else bool(getattr(scfg, "telemetry", False)))
        path = (os.environ.get("REPRO_TRACE_PATH", "").strip()
                or str(getattr(scfg, "trace_path", "") or ""))
        return cls(enabled=enabled, trace_path=path or None)

    # registry passthroughs (NULL when disabled)
    def counter(self, name: str, help: str = "", labels=()):
        return self.registry.counter(name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()):
        return self.registry.gauge(name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets: Tuple[float, ...] = LATENCY_BUCKETS_S):
        return self.registry.histogram(name, help, labels, buckets)

    def adopt(self, family):
        return self.registry.adopt(family)

    def event(self, ev: str, t: float, **fields) -> None:
        self.trace.event(ev, t, **fields)

    def render_prometheus(self) -> str:
        return self.registry.render_prometheus()

    def metrics_json(self) -> dict:
        return self.registry.to_json()

    def dump_trace(self, path: Optional[str] = None) -> str:
        """Canonical-JSON trace export; written to ``path`` (or the
        configured ``$REPRO_TRACE_PATH``) when one is set."""
        blob = self.trace.export_json()
        target = path or self.trace_path
        if target:
            with open(target, "w") as f:
                f.write(blob)
        return blob


# -- kernel profiling hooks (module scope: dispatch has no engine) ----------

_DISPATCH_CALLS = Counter(
    "rsr_dispatch_calls",
    "RSR serve-matmul dispatches (once per traced shape) by "
    "backend/regime/tile.", ("backend", "regime", "tile"))
_DISPATCH_SECONDS = Histogram(
    "rsr_dispatch_seconds",
    "Measured eager RSR matmul seconds (autotune candidates).",
    ("backend",))


def record_dispatch(backend: str, regime: str,
                    tile: Tuple[int, int, int]) -> None:
    """Count one ``rsr_serve_matmul`` dispatch.  Called at trace time
    (static shapes), so it fires once per compiled shape — always on,
    cost irrelevant, and deliberately free of env reads so the
    boundaries lint (RL203) stays clean."""
    _DISPATCH_CALLS.labels(
        backend=str(backend), regime=str(regime),
        tile="x".join(str(t) for t in tile)).inc()


def observe_dispatch_seconds(backend: str, seconds: float) -> None:
    """Record one eagerly-measured matmul duration (autotune loop)."""
    _DISPATCH_SECONDS.labels(backend=str(backend)).observe(float(seconds))


def kernel_families() -> Tuple[Counter, Histogram]:
    return _DISPATCH_CALLS, _DISPATCH_SECONDS


# -- trace analysis ---------------------------------------------------------

def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def latency_attribution(events: List[dict]) -> dict:
    """Per-lane queue/prefill/decode/total latency attribution from a
    trace event list.  Stages per request (first occurrence of each
    event): queue = submit→admit, prefill = admit→first_token, decode =
    first_token→finish, total = submit→finish.  Returns
    ``{lane: {stage: {"p50", "p99", "mean", "n"}}}`` keyed by the lane
    recorded at submit."""
    first: Dict[int, dict] = {}
    for e in events:
        rid = e.get("rid")
        if rid is None:
            continue
        slot = first.setdefault(rid, {})
        if e["ev"] not in slot:
            slot[e["ev"]] = e["t"]
        if e["ev"] == "submit":
            slot["lane"] = e.get("lane", 0)
    stages: Dict[int, Dict[str, List[float]]] = {}
    for rec in first.values():
        lane = int(rec.get("lane", 0))
        by = stages.setdefault(
            lane, {"queue": [], "prefill": [], "decode": [], "total": []})
        t_sub, t_adm = rec.get("submit"), rec.get("admit")
        t_tok, t_fin = rec.get("first_token"), rec.get("finish")
        if t_sub is not None and t_adm is not None:
            by["queue"].append(t_adm - t_sub)
        if t_adm is not None and t_tok is not None:
            by["prefill"].append(t_tok - t_adm)
        if t_tok is not None and t_fin is not None:
            by["decode"].append(t_fin - t_tok)
        if t_sub is not None and t_fin is not None:
            by["total"].append(t_fin - t_sub)
    out: dict = {}
    for lane, by in sorted(stages.items()):
        out[lane] = {
            stage: {"n": len(xs),
                    "mean": (sum(xs) / len(xs)) if xs else 0.0,
                    "p50": _percentile(xs, 0.50),
                    "p99": _percentile(xs, 0.99)}
            for stage, xs in by.items()}
    return out
