"""Tick-time invariant auditing for the serve plane.

The scheduler/pool state machine (block refcounts, warm list, hash
registry, host position mirror, overcommit budget) is all host-side
bookkeeping — when it drifts from the device cache the symptom is wrong
tokens many ticks later, with no breadcrumb back to the tick that broke
it.  This module is the breadcrumb: :func:`audit_scheduler` re-derives
every invariant from first principles in O(pool + batch) and raises a
diagnosable :class:`AuditError` (with a structured state dump) at the
FIRST tick the state machine is inconsistent.

Invariants checked (paged engines; the layout-independent ones always):

I1  **Refcount conservation** — the pool's per-block refcount vector
    equals the multiset of references held by the slots' block lists.
    A mismatch means a leak (freed twice / never freed) or a phantom
    reference.
I2  **No slot references a free or warm block** — a table entry into the
    free/warm set would let ``alloc`` hand a live request's block to
    someone else (the classic use-after-free).
I3  **Hash registry bijection** — ``hash → block`` and ``block → hash``
    agree both ways, and every warm-list entry is hash-registered with
    the matching hash (a warm block exists only to be matchable).
I4  **Block partition** — every pool block is in exactly one of
    {free, warm, referenced}; counts sum to the pool size.
I5  **Table consistency** — each slot's host table row holds exactly its
    block list (full region a prefix, ring region when armed, trash
    everywhere else).
I6  **Position mirror** — the scheduler's host per-slot position mirror
    equals the device cache positions (one O(batch) device fetch per
    audit; this is the only device sync the auditor costs).
I7  **Queue/slot disjointness** — no request is simultaneously queued
    and running, no duplicate rids, no terminal request still scheduled.
I8  **Overcommit budget** (priority plane) — the sum of running
    requests' worst-case block demands stays within
    ``overcommit * num_blocks``.

:func:`audit_snapshot` is the disk-side sibling (S1-S4): structural
vetting of a decoded checkpoint snapshot before ``restore()`` trusts it
— recovery (``durability.recover_scheduler``) runs it on every loaded
checkpoint, then ``audit_scheduler`` on the rebuilt plane.

Enable via ``ServeConfig.audit_interval=K`` (audit every K ticks;
0 disables) or the ``$REPRO_AUDIT_INTERVAL`` override — CI runs the
whole serve test suite at interval 1 so every green path also proves the
auditor quiet.  See ``repro/serve/__init__.py`` for the failure-mode
runbook (what each invariant's failure implies, how to reproduce with a
seeded ``FaultPlan``).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

__all__ = ["AuditError", "audit_pool", "audit_scheduler", "audit_snapshot"]


class AuditError(RuntimeError):
    """An invariant audit failed.  ``self.invariant`` names the check
    (I1..I8 per the module doc), ``self.state`` is the structured dump
    captured at failure time — everything needed to diagnose without a
    debugger attached to the (possibly long-gone) run."""

    def __init__(self, invariant: str, msg: str, state: dict):
        self.invariant = invariant
        self.state = state
        lines = [f"audit failed [{invariant}]: {msg}", "state dump:"]
        for k in sorted(state):
            lines.append(f"  {k} = {state[k]!r}")
        super().__init__("\n".join(lines))


def _pool_state(pool, slot_blocks) -> dict:
    return {
        "free": sorted(pool._free),
        "warm": list(pool._warm.keys()),
        "refs_nonzero": {int(b): int(r) for b, r in enumerate(pool._ref)
                         if r != 0},
        "hash_to_bid": {h.hex()[:12]: b for h, b in pool._hash_to_bid.items()},
        "slot_blocks": list(slot_blocks) if slot_blocks is not None else None,
        "pool_stats": dict(pool.stats),
    }


def audit_pool(pool, slot_blocks: Optional[list] = None) -> None:
    """Pool-only invariants (I1-I4).  ``slot_blocks`` is the engine's
    per-slot block-id lists; None skips the reference-side checks (I1,
    I2) — useful for unit tests that drive a bare BlockPool."""
    state = _pool_state(pool, slot_blocks)
    n = pool.num_blocks
    free = set(pool._free)
    warm = set(pool._warm.keys())
    if len(free) != len(pool._free):
        raise AuditError("I4", "duplicate block ids on the free list", state)
    if free & warm:
        raise AuditError("I4", f"blocks both free and warm: "
                         f"{sorted(free & warm)}", state)
    referenced = {int(b) for b in np.nonzero(pool._ref)[0]}
    if (bad := referenced & (free | warm)):
        raise AuditError("I4", f"blocks with refcount>0 on the free/warm "
                         f"list: {sorted(bad)}", state)
    if (neg := [int(b) for b in np.nonzero(pool._ref < 0)[0]]):
        raise AuditError("I1", f"negative refcounts at blocks {neg}", state)
    if len(free) + len(warm) + len(referenced) != n:
        raise AuditError(
            "I4", f"block partition broken: {len(free)} free + {len(warm)} "
            f"warm + {len(referenced)} referenced != pool {n} "
            f"(orphaned blocks leak capacity forever)", state)
    # I3: hash registry bijection + warm entries registered
    for h, bid in pool._hash_to_bid.items():
        if pool._bid_to_hash.get(bid) != h:
            raise AuditError("I3", f"hash {h.hex()[:12]} -> block {bid} but "
                             f"block maps back to "
                             f"{pool._bid_to_hash.get(bid)!r}", state)
    for bid, h in pool._bid_to_hash.items():
        if pool._hash_to_bid.get(h) != bid:
            raise AuditError("I3", f"block {bid} -> hash {h.hex()[:12]} but "
                             f"hash maps back to "
                             f"{pool._hash_to_bid.get(h)!r}", state)
    for bid, h in pool._warm.items():
        if pool._bid_to_hash.get(bid) != h:
            raise AuditError("I3", f"warm block {bid} not hash-registered "
                             f"(a warm block exists only to be matchable)",
                             state)
    if slot_blocks is None:
        return
    # I1: refcount conservation against the slots' held references
    counts = np.zeros(n, np.int64)
    for blocks in slot_blocks:
        for bid in blocks:
            counts[bid] += 1
    if not np.array_equal(counts, np.asarray(pool._ref)):
        diff = {int(b): (int(counts[b]), int(pool._ref[b]))
                for b in np.nonzero(counts != pool._ref)[0]}
        raise AuditError("I1", f"refcount vector != slot-held references "
                         f"(block: held, ref) {diff}", state)
    # I2: no slot holds a free/warm block
    held = {bid for blocks in slot_blocks for bid in blocks}
    if (bad := held & (free | warm)):
        raise AuditError("I2", f"slots reference free/warm blocks "
                         f"{sorted(bad)} — alloc could hand them out "
                         f"(use-after-free)", state)


def audit_snapshot(snap: dict) -> None:
    """Structural audit of a DECODED snapshot dict (S1-S4) before it is
    restored onto an engine — the gate between "the checkpoint's CRCs
    were fine" and "the scheduler will trust this state".  A snapshot
    failing here is treated by recovery like corruption would be one
    layer down: surfaced loudly, never silently restored.

    S1  required keys + basic types (``fingerprint``/``tick_no``/
        ``stats``/``key``/``queue``/``inflight``);
    S2  every request dict carries a usable identity (int ``rid``,
        list ``prompt``, positive ``max_new``, list ``generated`` not
        exceeding ``max_new``);
    S3  rid uniqueness across queue + inflight;
    S4  ``registered`` entries are ``[hash_hex, bid]`` with unique bids
        and unique hashes, and registered blocks come WITH their ``kv``
        payloads (encoded-array dicts) — a warm list without KV would
        hash-hit garbage.
    """
    state = {"snap_keys": sorted(snap) if isinstance(snap, dict) else None}
    if not isinstance(snap, dict):
        raise AuditError("S1", f"snapshot is {type(snap).__name__}, not a "
                         f"dict", state)
    for k, ty in (("fingerprint", (list, tuple)), ("tick_no", int),
                  ("stats", dict), ("key", list), ("queue", list),
                  ("inflight", list)):
        if not isinstance(snap.get(k), ty):
            raise AuditError(
                "S1", f"snapshot[{k!r}] missing or not "
                f"{getattr(ty, '__name__', ty)} "
                f"(got {type(snap.get(k)).__name__})", state)
    rids = []
    for where, reqs in (("queue", snap["queue"]),
                        ("inflight", snap["inflight"])):
        for d in reqs:
            state["bad_request"] = d if isinstance(d, dict) else repr(d)
            if not isinstance(d, dict) or not isinstance(d.get("rid"), int):
                raise AuditError("S2", f"{where} entry without an int rid",
                                 state)
            if not isinstance(d.get("prompt"), list) or not d["prompt"]:
                raise AuditError("S2", f"{where} request {d['rid']}: prompt "
                                 f"missing or empty", state)
            gen = d.get("generated", [])
            if not isinstance(gen, list) \
                    or not isinstance(d.get("max_new"), int) \
                    or d["max_new"] <= 0 or len(gen) > d["max_new"]:
                raise AuditError(
                    "S2", f"{where} request {d['rid']}: generated/max_new "
                    f"inconsistent ({len(gen) if isinstance(gen, list) else gen!r} "
                    f"vs {d.get('max_new')!r})", state)
            rids.append(d["rid"])
    state.pop("bad_request", None)
    if len(set(rids)) != len(rids):
        dup = sorted({r for r in rids if rids.count(r) > 1})
        state["rids"] = rids
        raise AuditError("S3", f"duplicate rids across snapshot queue + "
                         f"inflight: {dup}", state)
    reg = snap.get("registered") or []
    kv = snap.get("kv") or {}
    state["registered"] = len(reg)
    state["kv_entries"] = len(kv)
    bids, hashes = [], []
    for entry in reg:
        if (not isinstance(entry, (list, tuple)) or len(entry) != 2
                or not isinstance(entry[0], str)
                or not isinstance(entry[1], int)):
            state["bad_entry"] = repr(entry)
            raise AuditError("S4", "registered entry is not [hash_hex, bid]",
                             state)
        hashes.append(entry[0])
        bids.append(entry[1])
    if len(set(bids)) != len(bids) or len(set(hashes)) != len(hashes):
        raise AuditError("S4", f"registered bids/hashes not unique "
                         f"({len(bids)} entries)", state)
    if reg and not kv:
        raise AuditError("S4", f"{len(reg)} registered blocks but no kv "
                         f"payloads — restoring would warm-hit garbage",
                         state)
    for k, v in kv.items():
        if not (isinstance(v, dict) and v.get("__nd__")
                and "dtype" in v and "shape" in v and "data" in v):
            state["bad_kv_key"] = k
            raise AuditError("S4", f"kv[{k!r}] is not an encoded array",
                             state)


def audit_scheduler(sched) -> None:
    """Full scheduler audit (I1-I8; see module doc).  Raises AuditError
    on the first violated invariant; silent when consistent."""
    eng = sched.engine
    if eng.paged:
        audit_pool(eng.pool, eng._slot_blocks)
        lay = eng.layout
        state = _pool_state(eng.pool, eng._slot_blocks)
        state["tables"] = eng._tables.tolist()
        # I5: each host table row == exactly the slot's block list
        for i in range(eng.batch):
            row = eng._tables[i]
            real = [int(b) for b in row if b != lay.trash_block]
            if sorted(real) != sorted(eng._slot_blocks[i]):
                raise AuditError(
                    "I5", f"slot {i} table entries {sorted(real)} != held "
                    f"blocks {sorted(eng._slot_blocks[i])}", state)
            full = row[:lay.mb_full]
            fc = eng._full_count[i]
            if any(b == lay.trash_block for b in full[:fc]) or \
                    any(b != lay.trash_block for b in full[fc:]):
                raise AuditError(
                    "I5", f"slot {i} full region not a clean prefix of "
                    f"{fc} assigned blocks: {full.tolist()}", state)
    state = {
        "pos_host": list(sched._pos),
        "queue_rids": [r.rid for r in sched.queue],
        "slot_rids": [None if r is None else r.rid for r in sched.slots],
        "statuses": {r.rid: r.status.value
                     for r in sched.queue + [s for s in sched.slots
                                             if s is not None]},
    }
    # I6: host position mirror vs device cache positions
    dev_pos = np.asarray(jax.device_get(eng.cache["pos"]))
    state["pos_device"] = dev_pos.tolist()
    if list(dev_pos) != list(sched._pos):
        raise AuditError(
            "I6", "host position mirror diverged from device cache "
            "positions — overflow guards and paged reservations are "
            "operating on wrong offsets", state)
    # I7: queue/slot disjointness, rid uniqueness, status sanity
    queued = [r.rid for r in sched.queue]
    running = [r.rid for r in sched.slots if r is not None]
    if len(set(queued)) != len(queued):
        raise AuditError("I7", f"duplicate rids in queue: {queued}", state)
    if len(set(running)) != len(running):
        raise AuditError("I7", f"duplicate rids across slots: {running}",
                         state)
    if (both := set(queued) & set(running)):
        raise AuditError("I7", f"requests both queued and running: "
                         f"{sorted(both)}", state)
    for r in sched.queue:
        if r.done or r.status.terminal:
            raise AuditError("I7", f"terminal request {r.rid} "
                             f"({r.status.value}) still queued", state)
    for r in sched.slots:
        if r is not None and (r.done or r.status.terminal):
            raise AuditError("I7", f"terminal request {r.rid} "
                             f"({r.status.value}) still holds a slot", state)
    # I8: overcommit budget (priority plane only)
    if eng.paged and hasattr(sched, "overcommit"):
        worst = sched._running_worst()
        budget = sched.overcommit * eng.layout.num_blocks
        if worst > budget + 1e-9:
            state["running_worst"] = worst
            state["budget"] = budget
            raise AuditError(
                "I8", f"running worst-case demand {worst} blocks exceeds "
                f"overcommit budget {budget:.1f}", state)
