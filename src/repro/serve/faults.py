"""Deterministic multi-seam fault injection for the serve plane.

PR 6 left one failure seam: ``$REPRO_FAULT_ALLOC`` fails the Nth
``BlockPool.alloc`` call.  Production engines see a wider failure surface
— poisoned numerics out of a flaky accelerator, clock skew from NTP
steps, ticks inflated by host contention, transient prefill failures —
and each one exercises a different recovery path (quarantine, shedding,
EMA-driven hopeless detection, deferral).  A :class:`FaultPlan` is the
generalization: one seeded, fully deterministic schedule that can fire at
every seam the scheduler owns, so a chaos soak is reproducible from a
single spec string.

Seams (spec grammar, comma-separated events):

``alloc@N``
    The Nth ``BlockPool.alloc`` call (1-based, per pool, counted
    successful or not) raises ``BlockPoolExhausted`` — same semantics as
    ``$REPRO_FAULT_ALLOC`` (which remains the back-compat alias for
    alloc-only plans); each ordinal fires exactly once, so a retry of the
    same logical allocation succeeds.  Wired by composing onto the pool's
    existing ``fault_injector`` (:meth:`FaultPlan.chain_alloc`), so both
    sources of ordinals stay live.
``prefill@N``
    The Nth admission prefill (``Engine.prefill_into`` /
    ``Engine.begin_prefill_job``) raises :class:`PrefillFault` before
    touching allocator or cache state.  Transient: the scheduler rolls
    the slot back and retries next tick, exactly like an alloc fault.
``poison@T`` / ``poison@T:S``
    At scheduler tick T (1-based), the decode logits of ONE active slot
    (the ``S % n_active``-th, default S=0) are overwritten with NaN —
    the numeric-quarantine path must fail exactly that request
    (``FAILED_NUMERIC``) and leave every other row bitwise-unchanged.
``clock+S@T``
    The scheduler clock jumps forward S seconds at the START of tick T
    (an NTP-step / suspend-resume stand-in: deadlines expire en masse).
``slow+S@T``
    S seconds are added INSIDE tick T (at its end, before the duration
    is measured), inflating the tick-time EMA that drives
    deadline-hopeless shedding — a host-contention stand-in.
``torn@N``
    The Nth durable DISK write (1-based: one checkpoint temp-file write
    or one journal append, counted across both —
    ``repro.serve.durability``) is torn: only the first half of the
    buffer lands, a power-cut stand-in.  Recovery must truncate at the
    damage, never crash on it.
``flip@N``
    The Nth durable disk write lands with ONE bit flipped mid-buffer —
    silent media corruption the per-record CRC32 must catch, making the
    checkpoint fall back / the journal truncate.
``fsync@N``
    The Nth ``fsync`` the durability layer issues fails.  A checkpoint
    publish is ABORTED (the previous checkpoint stays newest, the plane
    keeps serving); a journal append is tolerated-and-counted (the
    event may be lost, like any torn tail).

``FaultPlan.random(seed)`` draws a randomized-but-deterministic plan
(same seed → same spec, printable via ``plan.spec`` and replayable via
``REPRO_FAULTS=<spec>``), which is what the chaos soak runs under.

Configuration: ``ServeConfig.fault_plan`` holds a spec string;
``$REPRO_FAULTS`` outranks it (same precedence rule as the other
``REPRO_*`` overrides).  The plan is stateful (per-seam counters) —
build a fresh one per scheduler run.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, FrozenSet, Optional

import numpy as np

from repro.serve import telemetry

__all__ = ["FaultPlan", "FaultClock", "PrefillFault", "env_fault_plan"]


class PrefillFault(RuntimeError):
    """Injected transient admission-prefill failure.  Raised by the engine
    BEFORE any allocator or cache mutation, so the scheduler's rollback
    (free the slot, defer, retry next tick) is exercised without any
    state to unwind — the retry must then succeed and produce the same
    tokens as a fault-free run."""


class FaultClock:
    """Injectable-clock wrapper adding a controllable forward offset.

    The scheduler's ``clock`` is replaced with one of these when a plan
    carries ``clock``/``slow`` events; ``advance()`` moves every
    subsequent reading forward — monotonicity is preserved (offsets are
    validated non-negative at parse time), so EDF ordering stays sane
    while deadlines expire early."""

    def __init__(self, base: Callable[[], float]):
        self.base = base
        self.offset = 0.0

    def __call__(self) -> float:
        return self.base() + self.offset

    def advance(self, seconds: float) -> None:
        self.offset += float(seconds)


def _bad(spec: str, tok: str, why: str) -> ValueError:
    return ValueError(
        f"fault plan {spec!r}: bad event {tok!r} ({why}); grammar is "
        f"alloc@N | prefill@N | poison@T[:S] | clock+SEC@T | slow+SEC@T | "
        f"torn@N | flip@N | fsync@N, comma-separated")


class FaultPlan:
    """A parsed, seeded-or-explicit fault schedule (see module doc).

    Stateful: the prefill counter and per-event ``fired`` tallies advance
    as the run consumes the plan, so construct one plan per scheduler.
    ``fired`` is the soak's ground truth that the chaos actually happened
    (a plan whose events never fire is a vacuous test).
    """

    def __init__(self, spec: str, *, alloc: FrozenSet[int],
                 prefill: FrozenSet[int], poison: Dict[int, int],
                 clock: Dict[int, float], slow: Dict[int, float],
                 torn: FrozenSet[int] = frozenset(),
                 flip: FrozenSet[int] = frozenset(),
                 fsync: FrozenSet[int] = frozenset()):
        self.spec = spec
        self.alloc = alloc
        self.prefill = prefill
        self.poison = poison
        self.clock = clock
        self.slow = slow
        self.torn = torn
        self.flip = flip
        self.fsync = fsync
        self._prefill_calls = 0
        self._disk_writes = 0
        self._fsync_calls = 0
        # dict-compatible counter view (telemetry.StatsView): every
        # existing `fired["seam"] += 1` / equality assert is unchanged;
        # exported as serve_fault_fired{key=} once a scheduler adopts it
        self.fired = telemetry.stats_counters(
            "serve_fault_fired",
            ("alloc", "prefill", "poison", "clock", "slow", "torn",
             "flip", "fsync"),
            help="Injected faults fired, by seam.")

    # -- construction ------------------------------------------------------

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a spec string (see module doc for the grammar)."""
        alloc: set[int] = set()
        prefill: set[int] = set()
        poison: Dict[int, int] = {}
        clock: Dict[int, float] = {}
        slow: Dict[int, float] = {}
        torn: set[int] = set()
        flip: set[int] = set()
        fsync: set[int] = set()
        for tok in (t.strip() for t in spec.split(",")):
            if not tok:
                continue
            try:
                if tok.startswith("alloc@"):
                    alloc.add(int(tok[len("alloc@"):]))
                elif tok.startswith("prefill@"):
                    prefill.add(int(tok[len("prefill@"):]))
                elif tok.startswith("torn@"):
                    torn.add(int(tok[len("torn@"):]))
                elif tok.startswith("flip@"):
                    flip.add(int(tok[len("flip@"):]))
                elif tok.startswith("fsync@"):
                    fsync.add(int(tok[len("fsync@"):]))
                elif tok.startswith("poison@"):
                    body = tok[len("poison@"):]
                    t, _, sel = body.partition(":")
                    poison[int(t)] = int(sel) if sel else 0
                elif tok.startswith("clock+"):
                    sec, _, t = tok[len("clock+"):].partition("@")
                    clock[int(t)] = float(sec)
                elif tok.startswith("slow+"):
                    sec, _, t = tok[len("slow+"):].partition("@")
                    slow[int(t)] = float(sec)
                else:
                    raise _bad(spec, tok, "unknown seam")
            except (ValueError, TypeError) as e:
                if isinstance(e, ValueError) and "fault plan" in str(e):
                    raise
                raise _bad(spec, tok, "unparsable numbers") from e
        for t, sec in list(clock.items()) + list(slow.items()):
            if sec < 0:
                raise _bad(spec, f"...+{sec}@{t}",
                           "negative skew would break clock monotonicity")
        return cls(spec, alloc=frozenset(alloc), prefill=frozenset(prefill),
                   poison=poison, clock=clock, slow=slow,
                   torn=frozenset(torn), flip=frozenset(flip),
                   fsync=frozenset(fsync))

    @classmethod
    def random(cls, seed: int, *, ticks: int = 64, n_alloc: int = 2,
               n_prefill: int = 1, n_poison: int = 1, n_clock: int = 1,
               n_slow: int = 2, n_torn: int = 1, n_flip: int = 1,
               n_fsync: int = 1, skew_s: tuple = (0.5, 3.0)) -> "FaultPlan":
        """Randomized-but-deterministic plan: same seed → same spec.

        Event ticks land in [2, ticks] (tick 1 is left clean so the run
        always makes some progress first), alloc/prefill ordinals in a
        small range that early admissions actually reach.  The generated
        ``spec`` round-trips through :meth:`parse`, so a failing soak is
        reproduced with ``REPRO_FAULTS=<printed spec>``.
        """
        rng = np.random.default_rng(seed)
        lo = max(2, min(2, ticks))
        parts = []
        for _ in range(n_alloc):
            parts.append(f"alloc@{int(rng.integers(2, 20))}")
        for _ in range(n_prefill):
            parts.append(f"prefill@{int(rng.integers(2, 8))}")
        for _ in range(n_poison):
            parts.append(f"poison@{int(rng.integers(lo, ticks + 1))}"
                         f":{int(rng.integers(0, 8))}")
        for _ in range(n_clock):
            sec = float(rng.uniform(*skew_s))
            parts.append(f"clock+{sec:.3f}@{int(rng.integers(lo, ticks + 1))}")
        for _ in range(n_slow):
            sec = float(rng.uniform(*skew_s))
            parts.append(f"slow+{sec:.3f}@{int(rng.integers(lo, ticks + 1))}")
        # disk seams: small ordinals a journaling run reaches quickly —
        # submits and periodic checkpoints each consume a write ordinal
        for _ in range(n_torn):
            parts.append(f"torn@{int(rng.integers(2, 16))}")
        for _ in range(n_flip):
            parts.append(f"flip@{int(rng.integers(2, 16))}")
        for _ in range(n_fsync):
            parts.append(f"fsync@{int(rng.integers(1, 8))}")
        return cls.parse(",".join(parts))

    # -- seam hooks (consumed by pool / engine / scheduler) ----------------

    @property
    def needs_clock(self) -> bool:
        return bool(self.clock or self.slow)

    def chain_alloc(self, prev: Optional[Callable[[int, int], bool]]
                    ) -> Optional[Callable[[int, int], bool]]:
        """Compose the plan's alloc ordinals ONTO an existing pool
        injector (e.g. one built from $REPRO_FAULT_ALLOC) — both keep
        firing.  Returns ``prev`` unchanged when the plan has no alloc
        events."""
        if not self.alloc:
            return prev

        def injector(call: int, n: int) -> bool:
            if call in self.alloc:
                self.fired["alloc"] += 1
                return True
            return bool(prev and prev(call, n))
        return injector

    def take_prefill(self) -> bool:
        """Advance the admission-prefill counter; True when this call is
        scheduled to fail (the engine then raises PrefillFault)."""
        self._prefill_calls += 1
        if self._prefill_calls in self.prefill:
            self.fired["prefill"] += 1
            return True
        return False

    def poison_row(self, tick: int, n_active: int) -> Optional[int]:
        """Active-row index whose decode logits tick ``tick`` poisons
        (None: no poisoning this tick / nothing active to poison)."""
        sel = self.poison.get(tick)
        if sel is None or n_active <= 0:
            return None
        self.fired["poison"] += 1
        return sel % n_active

    def tick_start_skew(self, tick: int) -> float:
        """Seconds the clock jumps at the start of ``tick`` (0.0: none)."""
        sec = self.clock.get(tick, 0.0)
        if sec:
            self.fired["clock"] += 1
        return sec

    def tick_end_skew(self, tick: int) -> float:
        """Seconds added inside ``tick`` before its duration is measured
        (0.0: none) — inflates the scheduler's tick-time EMA."""
        sec = self.slow.get(tick, 0.0)
        if sec:
            self.fired["slow"] += 1
        return sec

    def take_disk_write(self) -> Optional[str]:
        """Advance the durable disk-write counter (checkpoint temp-file
        writes and journal appends share one ordinal space — consumed by
        ``durability.CheckpointStore``); returns ``"torn"`` / ``"flip"``
        when this write is scheduled to corrupt, else None.  ``torn``
        outranks ``flip`` on a shared ordinal."""
        self._disk_writes += 1
        if self._disk_writes in self.torn:
            self.fired["torn"] += 1
            return "torn"
        if self._disk_writes in self.flip:
            self.fired["flip"] += 1
            return "flip"
        return None

    def take_fsync(self) -> bool:
        """Advance the fsync counter; True when this fsync is scheduled
        to fail (the store then aborts a checkpoint publish / tolerates
        a journal append)."""
        self._fsync_calls += 1
        if self._fsync_calls in self.fsync:
            self.fired["fsync"] += 1
            return True
        return False

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FaultPlan({self.spec!r}, fired={self.fired})"


def env_fault_plan(scfg_spec: str = "") -> Optional[FaultPlan]:
    """Resolve the active fault plan: ``$REPRO_FAULTS`` outranks the
    ``ServeConfig.fault_plan`` spec; empty/unset means no plan (None)."""
    spec = os.environ.get("REPRO_FAULTS", "").strip() or (scfg_spec or "")
    return FaultPlan.parse(spec) if spec.strip() else None
