"""Async request plane: priority lanes, deadlines, overcommit, preemption.

The paper's packed 1.6-bit weight stream makes single-chip decode cheap
enough that the serve stack, not the matmul, is the availability
bottleneck: the FIFO ``BatchScheduler`` either stalls a burst behind
worst-case block reservations or defers it indefinitely on pool
exhaustion.  This module is the production layer on top of it:

* :class:`PriorityScheduler` — a ``BatchScheduler`` subclass that replaces
  the FIFO/eager-reservation policy with priority lanes, deadline-aware
  ordering, lazy block allocation under a configurable overcommit budget,
  victim preemption on mid-decode pool exhaustion, and graceful
  degradation (TIMEOUT terminal states instead of exceptions, admission
  shedding, bounded preemption retries).  Fully synchronous — ``run()``
  still drains a queue deterministically, which is what the tests and
  benches drive.
* :class:`AsyncFrontend` — the asyncio serve loop over a
  ``PriorityScheduler``: per-token streaming callbacks, an awaitable
  result per request, and a ``serve()`` coroutine that interleaves
  scheduler ticks with the event loop so submissions land between ticks.

Admission policy
----------------
Queued requests are ordered by ``(effective lane, deadline, arrival)``:

* **Lanes**: ``Request.priority`` (0 = most urgent).  A request's
  *effective* lane improves by one for every ``ServeConfig.lane_aging_s``
  seconds it has waited (starvation-proof: any request eventually reaches
  lane 0).  Requests pinned by the bounded-retry policy (see below) jump
  every lane.
* **EDF within a lane**: earlier absolute deadline first; no deadline
  sorts last.  Ties break by arrival (FIFO).

Admission is *lazy* on a paged engine: only the prompt blocks plus one
headroom block are claimed up front (``Engine.can_admit(..., lazy=True)``)
and the decode horizon is extended block-by-block each tick
(``Engine.reserve_tokens``).  Two gates bound it: the lazy demand must fit
the pool's claimable blocks now, and the sum of running requests'
worst-case demands (``Engine.worst_case_blocks``) must stay within
``overcommit * kv_num_blocks``.  ``overcommit == 1.0`` therefore never
needs preemption (every running request's final footprint fits);
``> 1.0`` admits more traffic than the pool can hold at once and resolves
collisions by preemption.

Preemption
----------
When a decode-time extension finds the pool dry, the plane evicts the
victim with the *worst* ``(lane, -deadline, -arrival)`` ranking — lowest
priority first, furthest deadline within a lane — frees its blocks (the
hash-registered prompt blocks land on the pool's WARM list, still
matchable), counts ``Request.preemptions`` up, and requeues it with
status ``PREEMPTED``.  Re-admission prefills ``prompt + generated`` as
one sequence: the warm prefix blocks hash-hit, so only the generated
tail (plus any partial prompt block) is recomputed — the PR-4 warm-list
property, now load-bearing.  After ``ServeConfig.max_preemptions``
evictions a request is PINNED: never picked as a victim again and boosted
past every lane, so repeated preemption degrades its latency but cannot
live-lock it.

Deadlines and timeouts
----------------------
``Request.deadline_s`` is a completion budget in seconds from arrival,
measured on the scheduler's injectable ``clock`` (tests pass a fake).  It
is enforced at three points, always as the graceful ``TIMEOUT`` terminal
state, never as an exception:

* queued + expired → shed at admission, ``generated`` empty;
* queued + hopeless (the measured per-tick EMA says even the first token
  cannot land in time) → shed at admission;
* running + expired → evicted with the partial ``generated`` kept.
"""
from __future__ import annotations

import asyncio
import itertools
from typing import Callable, List, Optional

import numpy as np

from repro.serve import paging
from repro.serve.engine import (BatchScheduler, Engine, Request,
                                RequestStatus)

__all__ = ["PriorityScheduler", "AsyncFrontend"]

# consecutive no-progress ticks (nothing running, nothing admitted) before
# the plane declares itself wedged instead of spinning forever — a CI
# failsafe; deterministic fault injection recovers within one retry, so a
# healthy plane never gets near this
_MAX_BARREN_TICKS = 64


class PriorityScheduler(BatchScheduler):
    """Priority/deadline/overcommit request plane over the engine's slots.

    Drop-in for ``BatchScheduler`` (same ``submit()`` / ``tick()`` /
    ``run()`` surface): with default-priority, no-deadline requests and
    ``overcommit == 1.0`` it completes the same traffic, but admission is
    lazy on paged engines and ordering is policy-driven rather than FIFO.
    ``stats`` counts preemptions / sheds / timeouts / re-admissions for
    the bench harness.
    """

    def __init__(self, engine: Engine, *, clock=None):
        super().__init__(engine, clock=clock)
        scfg = engine.scfg
        self.overcommit = max(1.0, float(scfg.overcommit))
        self.max_preemptions = int(scfg.max_preemptions)
        self.aging_s = float(scfg.lane_aging_s)
        self.lazy = engine.paged
        self._tick_ema: Optional[float] = None    # seconds per decode tick
        self._barren = 0
        self.stats = {"ticks": 0, "preemptions": 0, "shed": 0,
                      "timeouts": 0, "readmissions": 0,
                      "readmission_hit_tokens": 0, "admissions": 0}

    # -- policy helpers ----------------------------------------------------

    def _pinned(self, req: Request) -> bool:
        """Bounded-retry policy: after K evictions the request completes at
        degraded priority (it ate K re-prefills) but is exempt from further
        preemption and jumps the admission queue — no live-lock."""
        return req.preemptions >= self.max_preemptions

    def _lane(self, req: Request, now: float) -> int:
        if self._pinned(req):
            return -1                  # ahead of every real lane
        if self.aging_s <= 0:
            return max(0, req.priority)
        aged = int((now - req.arrival) / self.aging_s)
        return max(0, req.priority - aged)

    def _order_key(self, req: Request, now: float):
        """Admission order: lane, then EDF (no deadline last), then FIFO."""
        dl = req.deadline
        return (self._lane(req, now), dl if dl is not None else float("inf"),
                req.arrival, req.rid)

    def _victim_key(self, req: Request, now: float):
        """Victim order (max wins): lowest priority lane first, furthest
        deadline within it, youngest arrival as the tie-break."""
        dl = req.deadline
        return (self._lane(req, now), dl if dl is not None else float("inf"),
                req.arrival)

    # -- graceful degradation ----------------------------------------------

    def _shed_queue(self, now: float, finished: list):
        """Drop queued requests whose deadline already passed or has become
        hopeless (even an immediate admission cannot land the first token
        in time, judged by the measured tick EMA).  TIMEOUT terminal state
        with a machine-readable reason — not an exception."""
        keep: List[Request] = []
        for req in self.queue:
            dl = req.deadline
            why = None
            if dl is not None:
                if now >= dl:
                    why = (f"request {req.rid}: shed at admission — "
                           f"deadline expired {now - dl:.3f}s ago while "
                           f"queued")
                elif self._tick_ema:
                    chunks = -(-len(req.prompt) //
                               max(1, self.engine.scfg.prefill_chunk))
                    eta = now + (chunks + 1) * self._tick_ema
                    if eta > dl:
                        why = (f"request {req.rid}: shed at admission — "
                               f"deadline hopeless (first-token eta "
                               f"+{eta - now:.3f}s, deadline in "
                               f"{dl - now:.3f}s)")
            if why is None:
                keep.append(req)
            else:
                req.status = RequestStatus.TIMEOUT
                req.error = why
                req.done = True
                req.completed_at = now
                self.stats["shed"] += 1
                finished.append(req)
        self.queue = keep

    def _timeout_running(self, now: float, finished: list):
        """Cut off running requests whose deadline passed: partial output
        stays in ``generated``, terminal status TIMEOUT (never raises)."""
        for i, req in enumerate(self.slots):
            if req is None or req.deadline is None or now < req.deadline:
                continue
            req.error = (f"request {req.rid}: deadline exceeded after "
                         f"{len(req.generated)}/{req.max_new} tokens")
            self.stats["timeouts"] += 1
            finished.append(self._finish(i, status=RequestStatus.TIMEOUT))

    # -- admission ---------------------------------------------------------

    def _running_worst(self) -> int:
        eng = self.engine
        return sum(eng.worst_case_blocks(len(r.prompt), r.max_new)
                   for r in self.slots if r is not None)

    def _admit(self, finished: list, events: list) -> bool:
        """Policy-ordered admission into free slots.  Stops at the first
        candidate that cannot be taken (capacity or budget) — admitting a
        smaller, lower-ranked request past it would invert priority; aging
        keeps that candidate from starving regardless."""
        eng = self.engine
        now = self.clock()
        budget = (self.overcommit * eng.layout.num_blocks
                  if eng.paged else None)
        progressed = False
        free = [i for i, s in enumerate(self.slots) if s is None]
        while free and self.queue:
            qi = min(range(len(self.queue)),
                     key=lambda j: self._order_key(self.queue[j], now))
            req = self.queue[qi]
            readmit = bool(req.generated)
            seq = (req.prompt if not readmit else
                   np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.generated, np.int32)]))
            remaining = req.max_new - len(req.generated)
            plan = True
            if eng.paged:
                worst = eng.worst_case_blocks(len(req.prompt), req.max_new)
                if self._running_worst() + worst > budget:
                    break
                plan = eng.can_admit(seq, remaining, lazy=True)
                if plan is None:
                    break
            slot = free.pop(0)
            hit_before = eng.pool.stats["hit_tokens"] if eng.paged else 0
            try:
                logits = eng.prefill_into(
                    slot, seq, reserve=0 if self.lazy else remaining,
                    plan=None if plan is True else plan)
            except paging.BlockPoolExhausted:
                # the plan said it fits but alloc failed (fault injection,
                # or a COW/warm race): roll the slot back and defer — the
                # next tick replans against the true pool state
                eng.free_slot(slot)
                break
            self.queue.pop(qi)
            progressed = True
            self.stats["admissions"] += 1
            if readmit:
                self.stats["readmissions"] += 1
                self.stats["readmission_hit_tokens"] += (
                    eng.pool.stats["hit_tokens"] - hit_before)
            req.status = RequestStatus.RUNNING
            tok = int(self._sample(logits[None, :])[0])
            req.generated.append(tok)
            self._emit(req, tok, events)
            self._pos[slot] = len(seq)
            self.slots[slot] = req
            if len(req.generated) >= req.max_new:
                finished.append(self._finish(slot))
                free.append(slot)
            else:
                self._next_tok[slot] = tok
        return progressed

    # -- preemption --------------------------------------------------------

    def _preempt(self, slot: int) -> Request:
        """Evict ``slot`` mid-decode: free its blocks (registered prompt
        blocks go WARM — matchable for the re-admission prefix hit) and
        requeue the request.  Its ``arrival`` is kept, so aging ranks it
        ahead of fresher traffic in the same lane."""
        req = self.slots[slot]
        req.preemptions += 1
        req.status = RequestStatus.PREEMPTED
        self.slots[slot] = None
        self.engine.free_slot(slot)
        self._pos[slot] = 0
        self.queue.append(req)
        self.stats["preemptions"] += 1
        return req

    def _pick_victim(self, now: float, exclude: int) -> Optional[int]:
        """Running slot to evict: worst ``_victim_key`` among non-pinned
        slots.  ``exclude`` (the slot needing blocks) is only eligible when
        it is the single running request — self-preemption then frees its
        own fragmented blocks for a clean warm re-admission."""
        cands = [i for i, r in enumerate(self.slots)
                 if r is not None and not self._pinned(r) and i != exclude]
        if cands:
            return max(cands,
                       key=lambda i: self._victim_key(self.slots[i], now))
        rest = [i for i, r in enumerate(self.slots)
                if r is not None and i != exclude]
        if rest:                       # all others pinned: last resort —
            # stalling the extension would wedge every request, which is
            # worse for the pinned victim too (it waits either way)
            return max(rest,
                       key=lambda i: self._victim_key(self.slots[i], now))
        if self.slots[exclude] is not None:
            return exclude             # alone: preempt self, re-admit warm
        return None

    def _extend_or_preempt(self, now: float):
        """Lazy-mode pre-decode reservation: every active slot's table must
        cover its next position before the batched step runs.  Pool
        exhaustion preempts victims (worst-ranked first) until the
        extension fits; the victim's own extension is skipped when it is
        evicted."""
        if not self.lazy:
            return
        eng = self.engine
        for i in range(eng.batch):
            if self.slots[i] is None:
                continue
            while (self.slots[i] is not None
                   and not eng.reserve_tokens(i, self._pos[i] + 1)):
                victim = self._pick_victim(now, exclude=i)
                if victim is None:
                    raise RuntimeError(
                        f"request plane wedged: slot {i} cannot extend its "
                        f"reservation and no victim remains "
                        f"(pool={eng.layout.num_blocks}, "
                        f"free={eng.pool.free_count})")
                self._preempt(victim)

    # -- the tick ----------------------------------------------------------

    def tick(self, finished: list) -> list:
        """One plane step: deadline enforcement (running cut-offs, queue
        shedding), policy-ordered admissions, lazy reservation extension
        with preemption, then one batched decode step."""
        events: list = []
        now = self.clock()
        self.stats["ticks"] += 1
        self._timeout_running(now, finished)
        self._shed_queue(now, finished)
        progressed = self._admit(finished, events)
        if not any(s is not None for s in self.slots):
            if self.queue and not progressed:
                self._barren += 1
                if self._barren > _MAX_BARREN_TICKS:
                    raise RuntimeError(
                        f"request plane stalled: {len(self.queue)} queued "
                        f"requests, no admission for {self._barren} ticks")
            return events
        self._barren = 0
        self._extend_or_preempt(now)
        if any(s is not None for s in self.slots):
            self._decode_once(finished, events)
        dt = self.clock() - now
        if dt > 0:
            self._tick_ema = (dt if self._tick_ema is None
                              else 0.8 * self._tick_ema + 0.2 * dt)
        return events


class AsyncFrontend:
    """asyncio serve loop over a :class:`PriorityScheduler`.

    ``submit()`` (sync, call from the event-loop thread) validates and
    enqueues a request, returning it immediately; ``result(req)`` awaits
    its terminal state; ``Request.on_token`` streams tokens as they are
    generated.  ``serve()`` runs until ``stop()``: each iteration is one
    scheduler tick followed by an ``await`` point, so concurrent
    coroutines (new submissions, consumers) interleave with decoding.
    ``drain()`` is the bounded variant — serve until the plane is idle and
    return everything that finished — which is what tests and benches use,
    typically under ``asyncio.wait_for`` as the dead-loop guard.

    Note the decode step itself is synchronous (one jitted device call);
    the event loop yields *between* ticks, not inside one.
    """

    def __init__(self, engine: Engine, *, clock=None):
        self.scheduler = PriorityScheduler(engine, clock=clock)
        self._next_rid = itertools.count()
        self._futures: dict[int, asyncio.Future] = {}
        self._finished: list[Request] = []
        self._wake: Optional[asyncio.Event] = None
        self._stopping = False

    def submit(self, prompt, max_new: int, *, priority: int = 0,
               deadline_s: Optional[float] = None,
               on_token: Optional[Callable[[Request, int], None]] = None,
               rid: Optional[int] = None) -> Request:
        """Enqueue one request; returns the live Request object (watch
        ``status`` / await ``result()``).  A request rejected at
        validation comes back already ``done`` with its terminal status."""
        req = Request(rid=rid if rid is not None else next(self._next_rid),
                      prompt=np.asarray(prompt, np.int32), max_new=max_new,
                      priority=priority, deadline_s=deadline_s,
                      on_token=on_token)
        self.scheduler.submit(req)
        if req.done:                   # rejected at submit: settle now
            self.scheduler.rejected.remove(req)
            self._settle(req)
        if self._wake is not None:
            self._wake.set()
        return req

    async def result(self, req: Request) -> Request:
        """Await a request's terminal state (serve()/drain() must be
        running for progress to happen)."""
        if req.done:
            return req
        fut = self._futures.get(req.rid)
        if fut is None:
            fut = self._futures[req.rid] = (
                asyncio.get_running_loop().create_future())
        await fut
        return req

    def _settle(self, req: Request):
        self._finished.append(req)
        fut = self._futures.pop(req.rid, None)
        if fut is not None and not fut.done():
            fut.set_result(req)

    def _step(self) -> list[Request]:
        finished: list[Request] = list(self.scheduler.rejected)
        self.scheduler.rejected = []
        self.scheduler.tick(finished)
        for req in finished:
            self._settle(req)
        return finished

    async def drain(self) -> list[Request]:
        """Tick until the plane is idle; returns every request that
        reached a terminal state during the drain (rejects included)."""
        drained = [r for r in self.scheduler.rejected]
        self.scheduler.rejected = []
        for req in drained:
            self._settle(req)
        while not self.scheduler.idle:
            drained.extend(self._step())
            await asyncio.sleep(0)
        return drained

    async def serve(self):
        """Serve until ``stop()``: tick while there is work, park on an
        event while idle (a submit() wakes the loop)."""
        self._wake = asyncio.Event()
        self._stopping = False
        try:
            while not self._stopping:
                if self.scheduler.idle:
                    self._wake.clear()
                    await self._wake.wait()
                    continue
                self._step()
                await asyncio.sleep(0)
        finally:
            self._wake = None

    def stop(self):
        self._stopping = True
        if self._wake is not None:
            self._wake.set()
