"""Async request plane: priority lanes, deadlines, overcommit, preemption.

The paper's packed 1.6-bit weight stream makes single-chip decode cheap
enough that the serve stack, not the matmul, is the availability
bottleneck: the FIFO ``BatchScheduler`` either stalls a burst behind
worst-case block reservations or defers it indefinitely on pool
exhaustion.  This module is the production layer on top of it:

* :class:`PriorityScheduler` — a ``BatchScheduler`` subclass that replaces
  the FIFO/eager-reservation policy with priority lanes, deadline-aware
  ordering, lazy block allocation under a configurable overcommit budget,
  victim preemption on mid-decode pool exhaustion, and graceful
  degradation (TIMEOUT terminal states instead of exceptions, admission
  shedding, bounded preemption retries).  Fully synchronous — ``run()``
  still drains a queue deterministically, which is what the tests and
  benches drive.
* :class:`AsyncFrontend` — the asyncio serve loop over a
  ``PriorityScheduler``: per-token streaming callbacks, an awaitable
  result per request, and a ``serve()`` coroutine that interleaves
  scheduler ticks with the event loop so submissions land between ticks.

Admission policy
----------------
Queued requests are ordered by ``(effective lane, deadline, arrival)``:

* **Lanes**: ``Request.priority`` (0 = most urgent).  A request's
  *effective* lane improves by one for every ``ServeConfig.lane_aging_s``
  seconds it has waited (starvation-proof: any request eventually reaches
  lane 0).  Requests pinned by the bounded-retry policy (see below) jump
  every lane.
* **EDF within a lane**: earlier absolute deadline first; no deadline
  sorts last.  Ties break by arrival (FIFO).

Admission is *lazy* on a paged engine: only the prompt blocks plus one
headroom block are claimed up front (``Engine.can_admit(..., lazy=True)``)
and the decode horizon is extended block-by-block each tick
(``Engine.reserve_tokens``).  Two gates bound it: the lazy demand must fit
the pool's claimable blocks now, and the sum of running requests'
worst-case demands (``Engine.worst_case_blocks``) must stay within
``overcommit * kv_num_blocks``.  ``overcommit == 1.0`` therefore never
needs preemption (every running request's final footprint fits);
``> 1.0`` admits more traffic than the pool can hold at once and resolves
collisions by preemption.

Preemption
----------
When a decode-time extension finds the pool dry, the plane evicts the
victim with the *worst* ``(lane, -deadline, -arrival)`` ranking — lowest
priority first, furthest deadline within a lane — frees its blocks (the
hash-registered prompt blocks land on the pool's WARM list, still
matchable), counts ``Request.preemptions`` up, and requeues it with
status ``PREEMPTED``.  Re-admission prefills ``prompt + generated`` as
one sequence: the warm prefix blocks hash-hit, so only the generated
tail (plus any partial prompt block) is recomputed — the PR-4 warm-list
property, now load-bearing.  After ``ServeConfig.max_preemptions``
evictions a request is PINNED: never picked as a victim again and boosted
past every lane, so repeated preemption degrades its latency but cannot
live-lock it.

Deadlines and timeouts
----------------------
``Request.deadline_s`` is a completion budget in seconds from arrival,
measured on the scheduler's injectable ``clock`` (tests pass a fake).  It
is enforced at three points, always as the graceful ``TIMEOUT`` terminal
state, never as an exception:

* queued + expired → shed at admission, ``generated`` empty;
* queued + hopeless (the measured per-tick EMA says even the first token
  cannot land in time) → shed at admission;
* running + expired → evicted with the partial ``generated`` kept.
"""
from __future__ import annotations

import asyncio
import itertools
import os
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import durability, faults, paging, telemetry
from repro.serve.engine import (BatchScheduler, Engine, Request,
                                RequestStatus)

__all__ = ["PriorityScheduler", "AsyncFrontend"]

# consecutive no-progress ticks (nothing running, nothing admitted) before
# the plane declares itself wedged instead of spinning forever — a CI
# failsafe; deterministic fault injection recovers within one retry, so a
# healthy plane never gets near this
_MAX_BARREN_TICKS = 64


class PriorityScheduler(BatchScheduler):
    """Priority/deadline/overcommit request plane over the engine's slots.

    Drop-in for ``BatchScheduler`` (same ``submit()`` / ``tick()`` /
    ``run()`` surface): with default-priority, no-deadline requests and
    ``overcommit == 1.0`` it completes the same traffic, but admission is
    lazy on paged engines and ordering is policy-driven rather than FIFO.
    ``stats`` counts preemptions / sheds / timeouts / re-admissions for
    the bench harness.
    """

    def __init__(self, engine: Engine, *, clock=None,
                 fault_plan: Optional[faults.FaultPlan] = None):
        super().__init__(engine, clock=clock)
        scfg = engine.scfg
        self.overcommit = max(1.0, float(scfg.overcommit))
        self.max_preemptions = int(scfg.max_preemptions)
        self.aging_s = float(scfg.lane_aging_s)
        self.lazy = engine.paged
        self._tick_ema: Optional[float] = None    # seconds per decode tick
        self._barren = 0
        # prefill-chunking budget: >0 caps admission/re-admission prefill
        # tokens per tick, longer tails span ticks as resumable jobs
        self.prefill_budget = max(
            0, int(getattr(scfg, "max_prefill_tokens_per_tick", 0)))
        self._tick_prefill_left: Optional[int] = None
        self._prefilling: dict[int, object] = {}  # slot -> PrefillJob
        # registry-backed counter view: the historical dict surface
        # (stats["k"] += 1, dict(stats), snapshot/restore) is unchanged;
        # exports see it as serve_sched_stats{key="..."}
        self.stats = telemetry.stats_counters(
            "serve_sched_stats",
            ("ticks", "preemptions", "shed", "timeouts", "readmissions",
             "readmission_hit_tokens", "admissions", "prefill_faults",
             "quarantined", "restored", "checkpoints", "journal_events"),
            help="Priority-scheduler lifecycle counters.")
        # fault-injection plan: explicit arg > $REPRO_FAULTS >
        # scfg.fault_plan.  Wired once here: alloc ordinals compose onto
        # the pool's existing injector ($REPRO_FAULT_ALLOC stays live as
        # the back-compat alias), the prefill seam hangs off the engine,
        # and clock/slow events wrap the injectable clock.
        self.fault_plan = (fault_plan if fault_plan is not None else
                           faults.env_fault_plan(
                               getattr(scfg, "fault_plan", "")))
        self._fault_clock: Optional[faults.FaultClock] = None
        if self.fault_plan is not None:
            engine.fault_plan = self.fault_plan
            if engine.paged:
                engine.pool.fault_injector = self.fault_plan.chain_alloc(
                    engine.pool.fault_injector)
            if self.fault_plan.needs_clock:
                self._fault_clock = faults.FaultClock(self.clock)
                self.clock = self._fault_clock
                engine.clock = self.clock   # keep the engine on the same
                                            # (now fault-skewed) time source
        # durability policy: $REPRO_CHECKPOINT_DIR / _INTERVAL outrank the
        # scfg fields (same precedence rule as every other REPRO_* knob).
        # A configured directory turns on the write-ahead journal on every
        # submit/terminal/preemption; checkpoints additionally fire every
        # `checkpoint_interval` ticks and/or `checkpoint_interval_s`
        # seconds of the (injectable, possibly fault-skewed) clock.
        self._ckpt_store: Optional[durability.CheckpointStore] = None
        self._last_ckpt_t: Optional[float] = None
        cdir = (os.environ.get("REPRO_CHECKPOINT_DIR", "").strip()
                or getattr(scfg, "checkpoint_dir", ""))
        env_iv = os.environ.get("REPRO_CHECKPOINT_INTERVAL", "").strip()
        self._ckpt_interval = (int(env_iv) if env_iv else
                               int(getattr(scfg, "checkpoint_interval", 0)))
        self._ckpt_interval_s = float(
            getattr(scfg, "checkpoint_interval_s", 0.0))
        if cdir:
            self._ckpt_store = durability.CheckpointStore(
                cdir, keep=int(getattr(scfg, "checkpoint_keep", 3)),
                faults=self.fault_plan)
        # observability: adopt every subsystem's counter view into the
        # engine's registry (views count regardless of the enabled flag;
        # adoption only makes them exportable), and pre-build the
        # profiling families (NULL no-ops when telemetry is off)
        tel = self.telemetry
        tel.adopt(self.stats)
        if engine.paged:
            tel.adopt(engine.pool.stats)
        if self.fault_plan is not None:
            tel.adopt(self.fault_plan.fired)
        if self._ckpt_store is not None:
            tel.adopt(self._ckpt_store.stats)
        self._phase_hist = tel.histogram(
            "serve_tick_phase_seconds",
            "Per-tick phase durations (schedule/prefill/decode/audit).",
            ("phase",))
        self._tick_hist = tel.histogram(
            "serve_tick_duration_seconds", "Whole-tick durations.")
        self._g_occupancy = tel.gauge(
            "serve_batch_occupancy", "Occupied batch slots at tick end.")
        self._g_pool_free = tel.gauge(
            "serve_pool_free_blocks", "Free KV blocks at tick end.")
        self._g_pool_warm = tel.gauge(
            "serve_pool_warm_blocks", "Warm (reclaimable) KV blocks.")
        self._g_pool_used = tel.gauge(
            "serve_pool_used_blocks", "Live-referenced KV blocks.")

    # -- durability: write-ahead journal + periodic checkpoints ------------

    def _journal(self, event: dict) -> None:
        if self._ckpt_store is not None:
            self._ckpt_store.append(event)
            self.stats["journal_events"] += 1

    def submit(self, req: Request):
        """Validate/enqueue (base behavior), then write-ahead journal the
        accepted request.  Submit-time rejects never enter the queue and
        settle synchronously with the caller, so they are not journaled;
        an accepted request that crashes before the next checkpoint is
        rebuilt from this event on recovery."""
        super().submit(req)
        if not req.done:
            self._journal({"ev": "submit", "req": req.to_json()})

    def checkpoint(self) -> bool:
        """Write one durable checkpoint of the current snapshot now
        (the periodic policy calls this; operators can force one).
        False = the publish was aborted (injected/real fsync failure) —
        the previous checkpoint stays newest and serving continues."""
        if self._ckpt_store is None:
            raise RuntimeError(
                "checkpoint(): no checkpoint directory configured (set "
                "ServeConfig.checkpoint_dir or $REPRO_CHECKPOINT_DIR)")
        ok = self._ckpt_store.write_checkpoint(self.snapshot())
        if ok:
            self.stats["checkpoints"] += 1
        self._last_ckpt_t = self.clock()   # failures also wait a period:
        return ok                          # no hot-loop retry storms

    def _maybe_checkpoint(self) -> None:
        due = (self._ckpt_interval > 0
               and self._tick_no % self._ckpt_interval == 0)
        if not due and self._ckpt_interval_s > 0:
            now = self.clock()
            if self._last_ckpt_t is None:
                self._last_ckpt_t = now
            due = now - self._last_ckpt_t >= self._ckpt_interval_s
        if due:
            self.checkpoint()

    # -- policy helpers ----------------------------------------------------

    def _pinned(self, req: Request) -> bool:
        """Bounded-retry policy: after K evictions the request completes at
        degraded priority (it ate K re-prefills) but is exempt from further
        preemption and jumps the admission queue — no live-lock."""
        return req.preemptions >= self.max_preemptions

    def _lane(self, req: Request, now: float) -> int:
        if self._pinned(req):
            return -1                  # ahead of every real lane
        if self.aging_s <= 0:
            return max(0, req.priority)
        aged = int((now - req.arrival) / self.aging_s)
        return max(0, req.priority - aged)

    def _order_key(self, req: Request, now: float):
        """Admission order: lane, then EDF (no deadline last), then FIFO."""
        dl = req.deadline
        return (self._lane(req, now), dl if dl is not None else float("inf"),
                req.arrival, req.rid)

    def _victim_key(self, req: Request, now: float):
        """Victim order (max wins): lowest priority lane first, furthest
        deadline within it, then CHEAPEST eviction — fewest generated
        tokens, since every generated token must re-prefill on
        re-admission (the prompt prefix rides the warm-list hit, the
        generated tail is recomputed), so invested work is protected —
        and youngest arrival as the final tie-break."""
        dl = req.deadline
        return (self._lane(req, now), dl if dl is not None else float("inf"),
                -len(req.generated), req.arrival)

    # -- graceful degradation ----------------------------------------------

    def _shed_queue(self, now: float, finished: list):
        """Drop queued requests whose deadline already passed or has become
        hopeless (even an immediate admission cannot land the first token
        in time, judged by the measured tick EMA).  TIMEOUT terminal state
        with a machine-readable reason — not an exception."""
        keep: List[Request] = []
        for req in self.queue:
            dl = req.deadline
            why = None
            if dl is not None:
                if now >= dl:
                    why = (f"request {req.rid}: shed at admission — "
                           f"deadline expired {now - dl:.3f}s ago while "
                           f"queued")
                elif self._tick_ema:
                    chunks = -(-len(req.prompt) //
                               max(1, self.engine.scfg.prefill_chunk))
                    eta = now + (chunks + 1) * self._tick_ema
                    if eta > dl:
                        why = (f"request {req.rid}: shed at admission — "
                               f"deadline hopeless (first-token eta "
                               f"+{eta - now:.3f}s, deadline in "
                               f"{dl - now:.3f}s)")
            if why is None:
                keep.append(req)
            else:
                req.status = RequestStatus.TIMEOUT
                req.error = why
                req.done = True
                req.completed_at = now
                self.stats["shed"] += 1
                self._trace("shed", rid=req.rid)
                finished.append(req)
        self.queue = keep

    def _timeout_running(self, now: float, finished: list):
        """Cut off running requests whose deadline passed: partial output
        stays in ``generated``, terminal status TIMEOUT (never raises)."""
        for i, req in enumerate(self.slots):
            if req is None or req.deadline is None or now < req.deadline:
                continue
            req.error = (f"request {req.rid}: deadline exceeded after "
                         f"{len(req.generated)}/{req.max_new} tokens")
            self.stats["timeouts"] += 1
            self._trace("timeout", rid=req.rid)
            finished.append(self._finish(i, status=RequestStatus.TIMEOUT))

    # -- admission ---------------------------------------------------------

    def _running_worst(self) -> int:
        eng = self.engine
        return sum(eng.worst_case_blocks(len(r.prompt), r.max_new)
                   for r in self.slots if r is not None)

    def _admit(self, finished: list, events: list) -> bool:
        """Policy-ordered admission into free slots.  Stops at the first
        candidate that cannot be taken (capacity or budget) — admitting a
        smaller, lower-ranked request past it would invert priority; aging
        keeps that candidate from starving regardless."""
        eng = self.engine
        now = self.clock()
        budget = (self.overcommit * eng.layout.num_blocks
                  if eng.paged else None)
        progressed = False
        free = [i for i, s in enumerate(self.slots)
                if s is None and i not in self._prefilling]
        while free and self.queue:
            if self.prefill_budget > 0 and self._tick_prefill_left <= 0:
                break                  # this tick's prefill budget is spent
            qi = min(range(len(self.queue)),
                     key=lambda j: self._order_key(self.queue[j], now))
            req = self.queue[qi]
            readmit = bool(req.generated)
            seq = (req.prompt if not readmit else
                   np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.generated, np.int32)]))
            remaining = req.max_new - len(req.generated)
            plan = True
            if eng.paged:
                worst = eng.worst_case_blocks(len(req.prompt), req.max_new)
                if self._running_worst() + worst > budget:
                    break
                plan = eng.can_admit(seq, remaining, lazy=True)
                if plan is None:
                    break
            slot = free.pop(0)
            hit_before = eng.pool.stats["hit_tokens"] if eng.paged else 0
            try:
                job = eng.begin_prefill_job(
                    slot, seq, reserve=0 if self.lazy else remaining,
                    plan=None if plan is True else plan)
            except paging.BlockPoolExhausted:
                # the plan said it fits but alloc failed (fault injection,
                # or a COW/warm race): roll the slot back and defer — the
                # next tick replans against the true pool state.
                # free_slot zeroes the slot's DEVICE position, so the host
                # mirror must follow or it stays offset forever (audit I6)
                eng.free_slot(slot)
                self._pos[slot] = 0
                break
            except faults.PrefillFault:
                # injected transient prefill failure: raised before any
                # allocator/cache mutation, so rollback is the same defer
                self.stats["prefill_faults"] += 1
                eng.free_slot(slot)
                self._pos[slot] = 0
                break
            self.queue.pop(qi)
            progressed = True
            self.stats["admissions"] += 1
            hit = 0
            if readmit:
                self.stats["readmissions"] += 1
                if eng.paged:
                    hit = eng.pool.stats["hit_tokens"] - hit_before
                    self.stats["readmission_hit_tokens"] += hit
            if self.telemetry.enabled:
                req._t_admit = now
                self.telemetry.trace.event(
                    "admit", now, rid=req.rid, slot=slot, readmit=readmit,
                    hit_tokens=int(hit))
            req.status = RequestStatus.RUNNING
            self.slots[slot] = req
            self._pos[slot] = 0
            ran = eng.step_prefill_job(
                job, 0 if self.prefill_budget <= 0
                else self._tick_prefill_left)
            if self._tick_prefill_left is not None:
                self._tick_prefill_left -= ran
            if job.done:
                self._job_go_live(slot, job, finished, events)
                if self.slots[slot] is None:
                    free.append(slot)
            else:
                self._prefilling[slot] = job
        return progressed

    def _job_go_live(self, slot: int, job, finished: list,
                     events: list) -> None:
        """Complete a prefill job: commit the sub cache, sample the
        request's next token off the prefill logits, and put the slot
        into the decode rotation (or finish it when max_new is met)."""
        logits = self.engine.finish_prefill_job(job)
        req = self.slots[slot]
        tok = int(self._sample(logits[None, :])[0])
        req.generated.append(tok)
        self._emit(req, tok, events)
        self._pos[slot] = job._len
        if len(req.generated) >= req.max_new:
            finished.append(self._finish(slot))
        else:
            self._next_tok[slot] = tok

    def _step_jobs(self, finished: list, events: list) -> None:
        """Advance in-flight prefill jobs within this tick's token budget
        (jobs first, then new admissions — a paused job holds claimed
        blocks, so finishing it is always the best use of the budget)."""
        for slot in sorted(self._prefilling):
            if (self._tick_prefill_left is not None
                    and self._tick_prefill_left <= 0):
                break
            job = self._prefilling[slot]
            ran = self.engine.step_prefill_job(
                job, 0 if self._tick_prefill_left is None
                else self._tick_prefill_left)
            if self._tick_prefill_left is not None:
                self._tick_prefill_left -= ran
            if job.done:
                del self._prefilling[slot]
                self._job_go_live(slot, job, finished, events)

    # -- preemption --------------------------------------------------------

    def _finish(self, i: int,
                status: RequestStatus = RequestStatus.OK) -> Request:
        """Finish/evict a slot; a mid-flight prefill job on it (timeout
        before the job completed) is cancelled first so the held sub and
        the table-row mask are dropped with the blocks."""
        job = self._prefilling.pop(i, None)
        if job is not None:
            self.engine.cancel_prefill_job(job)
        if status is RequestStatus.FAILED_NUMERIC:
            self.stats["quarantined"] += 1
        return super()._finish(i, status=status)

    def _preempt(self, slot: int) -> Request:
        """Evict ``slot`` mid-decode: free its blocks (registered prompt
        blocks go WARM — matchable for the re-admission prefix hit) and
        requeue the request.  Its ``arrival`` is kept, so aging ranks it
        ahead of fresher traffic in the same lane.  A mid-prefill-job slot
        (last-resort victim) abandons the job's partial work."""
        job = self._prefilling.pop(slot, None)
        if job is not None:
            self.engine.cancel_prefill_job(job)
        req = self.slots[slot]
        req.preemptions += 1
        req.status = RequestStatus.PREEMPTED
        self.slots[slot] = None
        self.engine.free_slot(slot)
        self._pos[slot] = 0
        self.queue.append(req)
        self.stats["preemptions"] += 1
        self._trace("preempt", rid=req.rid, slot=slot, n=req.preemptions)
        self._journal({"ev": "preempt", "rid": req.rid,
                       "n": req.preemptions})
        return req

    def _pick_victim(self, now: float, exclude: int) -> Optional[int]:
        """Running slot to evict: worst ``_victim_key`` among non-pinned
        decoding slots; then pinned slots (all others pinned: stalling the
        extension would wedge every request, which is worse for the pinned
        victim too); then mid-prefill-job slots (their partial prefill is
        lost — last resort).  ``exclude`` (the slot needing blocks) is
        only eligible when it is the single running request —
        self-preemption then frees its own fragmented blocks for a clean
        warm re-admission."""
        occupied = [i for i, r in enumerate(self.slots)
                    if r is not None and i != exclude]
        tiers = (
            [i for i in occupied if not self._pinned(self.slots[i])
             and i not in self._prefilling],
            [i for i in occupied if i not in self._prefilling],
            occupied,
        )
        for cands in tiers:
            if cands:
                return max(cands, key=lambda i: self._victim_key(
                    self.slots[i], now))
        if self.slots[exclude] is not None:
            return exclude             # alone: preempt self, re-admit warm
        return None

    def _extend_or_preempt(self, now: float):
        """Lazy-mode pre-decode reservation: every active slot's table must
        cover its next position before the batched step runs.  Pool
        exhaustion preempts victims (worst-ranked first) until the
        extension fits; the victim's own extension is skipped when it is
        evicted."""
        if not self.lazy:
            return
        eng = self.engine
        for i in range(eng.batch):
            if self.slots[i] is None or i in self._prefilling:
                continue              # job slots reserved everything at
                                      # begin; they are not decoding yet
            while (self.slots[i] is not None
                   and not eng.reserve_tokens(i, self._pos[i] + 1)):
                victim = self._pick_victim(now, exclude=i)
                if victim is None:
                    raise RuntimeError(
                        f"request plane wedged: slot {i} cannot extend its "
                        f"reservation and no victim remains "
                        f"(pool={eng.layout.num_blocks}, "
                        f"free={eng.pool.free_count})")
                self._preempt(victim)

    # -- the tick ----------------------------------------------------------

    def _decoding_slots(self) -> list[int]:
        """Occupied slots minus those whose admission prefill is still a
        mid-flight job (their device table rows are masked to trash; they
        join the decode rotation when the job finishes)."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and i not in self._prefilling]

    def _filter_logits(self, logits, active: list[int]):
        """Fault-plan seam: poison one active row's decode logits with NaN
        at the scheduled tick — the quarantine guard downstream must then
        fail exactly that request."""
        plan = self.fault_plan
        if plan is not None and active:
            row = plan.poison_row(self._tick_no, len(active))
            if row is not None:
                logits = logits.at[active[row], :].set(jnp.nan)
        return logits

    def _apply_end_skew(self):
        """Fault-plan seam: inflate this tick's measured duration (the
        EMA driving deadline-hopeless shedding) by advancing the wrapped
        clock before the duration is read."""
        if self.fault_plan is not None and self._fault_clock is not None:
            skew = self.fault_plan.tick_end_skew(self._tick_no)
            if skew:
                self._fault_clock.advance(skew)

    def tick(self, finished: list) -> list:
        """One plane step: injected clock jumps, deadline enforcement
        (running cut-offs, queue shedding), in-flight prefill jobs, then
        policy-ordered admissions — both within the tick's prefill token
        budget — lazy reservation extension with preemption, one batched
        decode step, and the end-of-tick invariant audit.  With a
        checkpoint store configured, every terminal transition this tick
        produced is write-ahead journaled (exact final tokens — recovery
        reports them verbatim, never recomputes) and the periodic
        checkpoint policy runs after the audit, so only audited-
        consistent states reach disk."""
        n_done = len(finished)
        events = self._tick_inner(finished)
        if self._ckpt_store is not None:
            for req in finished[n_done:]:
                self._journal({"ev": "terminal", "req": req.to_json()})
            self._maybe_checkpoint()
        return events

    def _tick_inner(self, finished: list) -> list:
        events: list = []
        self._tick_no += 1
        if self.fault_plan is not None and self._fault_clock is not None:
            skew = self.fault_plan.tick_start_skew(self._tick_no)
            if skew:
                self._fault_clock.advance(skew)
        now = self.clock()
        # phase profiler: extra clock reads happen ONLY when telemetry is
        # on, so disabled-mode tick behavior (and fake-clock tests) is
        # bit-for-bit the pre-telemetry one
        prof = self.telemetry.enabled
        pt = now

        def mark(phase: str) -> None:
            nonlocal pt
            if prof:
                t = self.clock()
                self._phase_hist.labels(phase=phase).observe(t - pt)
                pt = t

        self.stats["ticks"] += 1
        self._timeout_running(now, finished)
        self._shed_queue(now, finished)
        mark("schedule")
        self._tick_prefill_left = (self.prefill_budget
                                   if self.prefill_budget > 0 else None)
        self._step_jobs(finished, events)
        progressed = self._admit(finished, events)
        mark("prefill")
        if not any(s is not None for s in self.slots):
            if self.queue and not progressed:
                self._barren += 1
                if self._barren > _MAX_BARREN_TICKS:
                    raise RuntimeError(
                        f"request plane stalled: {len(self.queue)} queued "
                        f"requests, no admission for {self._barren} ticks")
            self._apply_end_skew()
            self._maybe_audit()
            mark("audit")
            self._observe_tick_gauges(now)
            return events
        self._barren = 0
        self._extend_or_preempt(now)
        if self._decoding_slots():
            self._decode_once(finished, events)
        mark("decode")
        self._apply_end_skew()
        dt = self.clock() - now
        if dt > 0:
            self._tick_ema = (dt if self._tick_ema is None
                              else 0.8 * self._tick_ema + 0.2 * dt)
        self._maybe_audit()
        mark("audit")
        self._observe_tick_gauges(now)
        return events

    def _observe_tick_gauges(self, tick_start: float) -> None:
        """Tick-end occupancy/pool gauges + whole-tick duration (enabled
        mode only — every call here is a no-op on NULL metrics, but the
        guard also skips the clock read)."""
        if not self.telemetry.enabled:
            return
        self._tick_hist.observe(self.clock() - tick_start)
        self._g_occupancy.set(
            sum(1 for s in self.slots if s is not None))
        eng = self.engine
        if eng.paged:
            free = eng.pool.free_count    # claimable = truly free + warm
            self._g_pool_free.set(free)
            self._g_pool_warm.set(eng.pool.warm_count)
            self._g_pool_used.set(eng.layout.num_blocks - free)

    # -- crash-safe snapshot / restore -------------------------------------

    def _fingerprint(self) -> tuple:
        """Engine-compatibility stamp a snapshot must match to restore."""
        eng = self.engine
        lay = eng.layout
        return (eng.cfg.name, eng.scfg.max_seq_len, eng.batch,
                None if lay is None else (lay.block_size, lay.num_blocks,
                                          lay.mb_full, lay.mb_ring))

    @staticmethod
    def _norm_fp(fp) -> tuple:
        """Fingerprint comparison form: a JSON round-trip turns tuples
        into lists, so both sides normalize to nested tuples."""
        return tuple(tuple(x) if isinstance(x, (list, tuple)) else x
                     for x in fp)

    def snapshot(self) -> dict:
        """Serialize the plane's complete host-side state — queued and
        inflight requests (mid-prefill-job ones included), scheduler
        counters, PRNG key, and the allocator's hash-registered blocks
        WITH their device KV contents — into a deep, JSON-serializable
        dict: every leaf is a plain int/float/str/list/dict (KV arrays
        ride ``durability.encode_array``; ``on_token`` callbacks and the
        frontend's futures are stripped, flagged per-request as
        ``streaming``).  Deep means mutation-isolated too: continued
        ticking after ``snapshot()`` returns cannot change the dict, so
        a checkpoint writer can serialize it at leisure.

        The design insight that keeps this small: per-slot device state
        does not need serializing.  An inflight request is resumed by the
        plane's existing PREEMPTED re-admission path (prefill of
        ``prompt + generated``), and the only thing that makes that cheap
        is the warm list — so a snapshot is exactly {requests} +
        {registered prompt blocks' KV}.  Greedy tokens are a pure
        function of the token sequence, so the resumed stream is bitwise-
        continuous whether the prefix blocks were exported (tail-only
        re-prefill) or not (full re-prefill on non-sharing families —
        same tokens, just slower).
        """
        eng = self.engine
        snap = {
            "fingerprint": self._fingerprint(),
            "tick_no": self._tick_no,
            "tick_ema": self._tick_ema,
            "stats": dict(self.stats),
            "key": np.asarray(jax.device_get(self._key)).tolist(),
            "queue": [r.to_json() for r in self.queue],
            "inflight": [r.to_json() for r in self.slots
                         if r is not None],
        }
        if eng.paged:
            pool = eng.pool
            # warm blocks first in LRU order, then resident-registered —
            # restore seeds them in this order, preserving relative age
            bids = list(pool._warm.keys()) + [
                bid for bid in pool._bid_to_hash if bid not in pool._warm]
            snap["registered"] = [[pool._bid_to_hash[bid].hex(), int(bid)]
                                  for bid in bids]
            snap["kv"] = {k: durability.encode_array(v)
                          for k, v in eng.export_blocks(bids).items()}
        return snap

    def restore(self, snap: dict) -> None:
        """Rebuild a snapshotted plane onto THIS (fresh) scheduler/engine:
        upload the registered blocks' KV into the same physical block
        ids, seat them on the warm list (matchable, refcount 0), and
        requeue every snapshotted request — inflight ones as PREEMPTED
        re-admissions whose prompt blocks warm-hit, so only the generated
        tail re-prefills and the greedy stream continues bitwise where
        the crash cut it.  Raises on a fingerprint mismatch or a
        non-fresh engine."""
        if self._norm_fp(snap["fingerprint"]) != self._norm_fp(
                self._fingerprint()):
            raise ValueError(
                f"snapshot fingerprint {snap['fingerprint']} does not "
                f"match this engine {self._fingerprint()}")
        eng = self.engine
        if not self.idle:
            raise RuntimeError("restore() requires an idle scheduler")
        if eng.paged and eng.pool.free_count != eng.layout.num_blocks:
            raise RuntimeError("restore() requires a fresh engine "
                               "(blocks already allocated)")
        if eng.paged and snap.get("registered"):
            bids = [bid for _h, bid in snap["registered"]]
            for h_hex, bid in snap["registered"]:
                eng.pool.seed_warm(bid, bytes.fromhex(h_hex))
            eng.import_blocks(bids, {k: durability.decode_array(v)
                                     for k, v in snap["kv"].items()})
        for d in snap["inflight"] + snap["queue"]:
            req = Request.from_json(d)
            req.done = False
            # the re-admission path keys off generated, not off the label;
            # PREEMPTED vs QUEUED here is observability
            req.status = (RequestStatus.PREEMPTED if req.generated
                          else RequestStatus.QUEUED)
            self.queue.append(req)
        self._tick_no = int(snap["tick_no"])
        self._tick_ema = snap["tick_ema"]
        # per-key assignment into the registry-adopted view (replacing the
        # view object would detach the exporter)
        for k, v in snap["stats"].items():
            self.stats[k] = v
        self.stats["restored"] = (self.stats.get("restored", 0)
                                  + len(snap["inflight"]))
        self._key = jnp.asarray(np.asarray(snap["key"], np.uint32))


class AsyncFrontend:
    """asyncio serve loop over a :class:`PriorityScheduler`.

    ``submit()`` (sync, call from the event-loop thread) validates and
    enqueues a request, returning it immediately; ``result(req)`` awaits
    its terminal state; ``Request.on_token`` streams tokens as they are
    generated.  ``serve()`` runs until ``stop()``: each iteration is one
    scheduler tick followed by an ``await`` point, so concurrent
    coroutines (new submissions, consumers) interleave with decoding.
    ``drain()`` is the bounded variant — serve until the plane is idle and
    return everything that finished — which is what tests and benches use,
    typically under ``asyncio.wait_for`` as the dead-loop guard.

    Note the decode step itself is synchronous (one jitted device call);
    the event loop yields *between* ticks, not inside one.
    """

    def __init__(self, engine: Engine, *, clock=None,
                 scheduler: Optional[PriorityScheduler] = None):
        self.scheduler = (scheduler if scheduler is not None
                          else PriorityScheduler(engine, clock=clock))
        self._next_rid = itertools.count()
        self._futures: dict[int, asyncio.Future] = {}
        self._finished: list[Request] = []
        self._wake: Optional[asyncio.Event] = None
        self._stopping = False
        self.recovery_report: Optional[dict] = None

    @classmethod
    def recover(cls, engine: Engine, *, clock=None,
                dirpath: Optional[str] = None) -> "AsyncFrontend":
        """Boot a frontend from the on-disk checkpoint/journal state
        (``durability.recover_scheduler``: newest valid checkpoint +
        journal-tail replay, I1-I8 audited).  ``recovery_report`` holds
        the ladder's outcome; requests whose terminal transition was
        journaled after the checkpoint arrive there already settled
        (``report["completed"]``) and in ``_finished``.  Fresh rids
        continue past every recovered one, so recovered and new traffic
        never collide."""
        sched, report = durability.recover_scheduler(
            engine, clock=clock, dirpath=dirpath)
        fe = cls(engine, clock=clock, scheduler=sched)
        fe.recovery_report = report
        fe._finished.extend(report["completed"])
        seen = [r.rid for r in sched.queue] + \
            [r.rid for r in report["completed"]]
        fe._next_rid = itertools.count(max(seen, default=-1) + 1)
        return fe

    def submit(self, prompt, max_new: int, *, priority: int = 0,
               deadline_s: Optional[float] = None,
               on_token: Optional[Callable[[Request, int], None]] = None,
               rid: Optional[int] = None) -> Request:
        """Enqueue one request; returns the live Request object (watch
        ``status`` / await ``result()``).  A request rejected at
        validation comes back already ``done`` with its terminal status."""
        req = Request(rid=rid if rid is not None else next(self._next_rid),
                      prompt=np.asarray(prompt, np.int32), max_new=max_new,
                      priority=priority, deadline_s=deadline_s,
                      on_token=on_token)
        self.scheduler.submit(req)
        if req.done:                   # rejected at submit: settle now
            self.scheduler.rejected.remove(req)
            self._settle(req)
        if self._wake is not None:
            self._wake.set()
        return req

    async def result(self, req: Request) -> Request:
        """Await a request's terminal state (serve()/drain() must be
        running for progress to happen)."""
        if req.done:
            return req
        fut = self._futures.get(req.rid)
        if fut is None:
            fut = self._futures[req.rid] = (
                asyncio.get_running_loop().create_future())
        await fut
        return req

    def _settle(self, req: Request):
        self._finished.append(req)
        fut = self._futures.pop(req.rid, None)
        if fut is not None and not fut.done():
            fut.set_result(req)

    def _step(self) -> list[Request]:
        finished: list[Request] = list(self.scheduler.rejected)
        self.scheduler.rejected = []
        self.scheduler.tick(finished)
        for req in finished:
            self._settle(req)
        return finished

    async def drain(self) -> list[Request]:
        """Tick until the plane is idle; returns every request that
        reached a terminal state during the drain (rejects included)."""
        drained = [r for r in self.scheduler.rejected]
        self.scheduler.rejected = []
        for req in drained:
            self._settle(req)
        while not self.scheduler.idle:
            drained.extend(self._step())
            await asyncio.sleep(0)
        return drained

    async def serve(self):
        """Serve until ``stop()``: tick while there is work, park on an
        event while idle (a submit() wakes the loop)."""
        self._wake = asyncio.Event()
        self._stopping = False
        try:
            while not self._stopping:
                if self.scheduler.idle:
                    self._wake.clear()
                    await self._wake.wait()
                    continue
                self._step()
                await asyncio.sleep(0)
        finally:
            self._wake = None

    def stop(self):
        self._stopping = True
        if self._wake is not None:
            self._wake.set()

    # -- observability (transport-shaped: an HTTP frontend serves these
    # verbatim as /metrics and /trace) ---------------------------------

    @property
    def telemetry(self) -> telemetry.Telemetry:
        return self.scheduler.telemetry

    def metrics(self) -> str:
        """Prometheus text exposition of every registered family
        (adopted stats views always; registry families when enabled)."""
        return self.telemetry.render_prometheus()

    def metrics_json(self) -> dict:
        """JSON snapshot of the same registry state."""
        return self.telemetry.metrics_json()

    def dump_trace(self, path: Optional[str] = None) -> str:
        """Canonical-JSON request-lifecycle trace export (byte-
        deterministic under an injected clock); also written to ``path``
        or ``$REPRO_TRACE_PATH`` when configured."""
        return self.telemetry.dump_trace(path)
