"""Serving subsystem: the RSR engine, continuous batching, the block-paged
KV cache, and the async request plane.

* ``engine``   — ``Engine`` (chunked prefill + decode over one jitted
  step), ``Request`` / ``RequestStatus``, and ``BatchScheduler``
  (continuous batching with validate-at-submit; strict FIFO, eager
  worst-case block reservation).
* ``paging``   — ``PagedLayout`` geometry, the host-side ``BlockPool``
  allocator (refcounts, chained prefix hashing, copy-on-write, the LRU
  warm list of freed-but-still-registered blocks, and the deterministic
  fault-injection seam), ``block_hashes``.
* ``frontend`` — the production request plane: ``PriorityScheduler``
  (priority lanes, deadlines, overcommit + preemption, budgeted prefill
  jobs, crash-safe ``snapshot()``/``restore()``) and ``AsyncFrontend``
  (asyncio serve loop with per-token streaming).
* ``faults``   — ``FaultPlan``: one seeded, deterministic schedule that
  fires at every failure seam the plane owns (allocator, admission
  prefill, decode numerics, clock jumps, inflated ticks); replayable
  from a spec string (``$REPRO_FAULTS``).
* ``audit``    — ``audit_scheduler`` / ``audit_pool``: tick-time
  re-derivation of every host-side invariant (refcounts, hash registry,
  warm list, table rows, position mirror, overcommit budget), raising a
  diagnosable ``AuditError`` at the first inconsistent tick;
  ``audit_snapshot`` is the disk-side sibling (structural vetting of a
  decoded checkpoint before ``restore()`` trusts it).
* ``durability`` — the disk half of crash safety: ``CheckpointStore``
  (versioned, CRC-checksummed checkpoints published atomically via
  temp-file + fsync + rename, monotonic sequence numbers, keep-last-K
  retention) plus a write-ahead request journal between checkpoints,
  and ``recover_scheduler`` (newest VALID checkpoint + journal-tail
  replay, corruption falls back instead of raising).
* ``telemetry`` — the observability plane: a typed metrics registry
  (counters / gauges / fixed-bucket histograms with labels; the shared
  no-op metric when disabled), dict-compatible ``StatsView`` counter
  families behind every legacy ``stats`` dict, deterministic
  request-lifecycle tracing on the scheduler's injectable clock, and
  Prometheus/JSON export through ``AsyncFrontend.metrics()`` /
  ``dump_trace()``.  See the Observability section below.

Request-plane guide
-------------------
``BatchScheduler`` is the conservative baseline: admission reserves a
request's worst case (``prompt + max_new`` blocks) up front, so a decode
step can never hit pool exhaustion, at the cost of FIFO head-of-line
blocking and pessimistic capacity.  ``frontend.PriorityScheduler`` is the
production policy on the same tick machinery:

* **Priority lanes** — ``Request.priority`` (0 = most urgent).  Admission
  orders the queue by *effective* lane, which improves one step per
  ``ServeConfig.lane_aging_s`` seconds of queue wait: a lane-3 request
  that has waited ``3 * lane_aging_s`` competes at lane 0, so no lane
  starves.
* **Deadlines (EDF)** — within a lane, earliest absolute deadline
  (``arrival + deadline_s``) first; requests without deadlines sort last.
  Deadlines are *enforced*, not just ordered by: an expired running
  request is cut off with terminal status ``TIMEOUT`` and its partial
  ``generated`` output kept; an expired or hopeless queued request (the
  measured per-tick EMA shows its first token cannot land in time) is
  shed at admission, also ``TIMEOUT`` — graceful terminal states with
  machine-readable reasons, never exceptions.
* **Lazy allocation + overcommit** — admission claims only the prompt
  blocks plus one headroom block; the decode horizon grows block-by-block
  each tick (``Engine.reserve_tokens``).  The admission gate additionally
  keeps the sum of running requests' worst-case demands within
  ``ServeConfig.overcommit * kv_num_blocks``.  At ``1.0`` every running
  request's final footprint is guaranteed to fit (preemption never
  fires); above it the plane deliberately oversubscribes and resolves
  collisions by preemption.
* **Preemption with bounded retry** — see the pressure narrative below.
  After ``ServeConfig.max_preemptions`` evictions a request is pinned:
  exempt from further eviction and boosted past every lane, so repeated
  preemption degrades its latency but cannot live-lock it.

What happens under pool pressure (the state narrative)
------------------------------------------------------
A request moves through ``QUEUED → RUNNING → OK`` when the pool is easy.
Under pressure the plane walks this ladder, gentlest first:

1. **Defer** — admission finds the lazy plan does not fit the pool's
   claimable blocks now (or the overcommit budget is full): the request
   stays QUEUED.  Aging meanwhile raises its effective priority.
2. **Extend-or-preempt** — a RUNNING slot's next decode position crosses
   a block boundary and ``reserve_tokens`` finds the pool dry.  The plane
   evicts the victim with the worst ``(lane, furthest-deadline)`` rank:
   status PREEMPTED, blocks freed (hash-registered prompt blocks land on
   the WARM list, still matchable), request re-queued with its original
   arrival (aging credit kept).  Re-admission prefills ``prompt +
   generated`` as one sequence — the warm prefix blocks hash-hit, so only
   the generated tail re-prefills, and greedy tokens continue bitwise
   exactly where they left off.
3. **Pin** — after ``max_preemptions`` evictions the request re-enters
   ahead of every lane and is never chosen as a victim again.
4. **Shed / timeout** — a deadline turns pressure into a terminal state:
   queued-and-late becomes TIMEOUT with empty output, running-and-late
   becomes TIMEOUT with partial output.  Requests that can *never* fit
   (worst case exceeds the whole pool) never enter the queue at all:
   REJECTED_CAPACITY at ``submit()``, just as malformed ones are
   REJECTED_VALIDATION.

``REPRO_*`` environment variables
---------------------------------
=====================  ==================================================
``REPRO_RSR_BACKEND``  Force the RSR matmul backend (``pallas`` |
                       ``pallas_interpret`` | ``scatter``); outranks
                       ``ModelConfig.rsr_backend`` in
                       ``kernels.dispatch``.
``REPRO_PAGED_ATTN``   Force the paged scoring backend (``kernel`` |
                       ``gather``); outranks ``ServeConfig.paged_attn``
                       (see below).
``REPRO_AUTOTUNE_CACHE``  Path of the kernel autotune cache file
                       (default: ``autotune_cache.json`` at the repo
                       root in a src-layout checkout, else
                       ``~/.cache/repro-rsr/autotune_cache.json``).  A
                       malformed file raises ``kernels.dispatch
                       .AutotuneCacheError`` before any table mutation
                       (at import time it is logged and the static
                       tables stand).
``REPRO_FAULT_ALLOC``  Deterministic allocator fault injection:
                       comma-separated 1-based ordinals of ``BlockPool
                       .alloc`` calls that raise ``BlockPoolExhausted``
                       (e.g. ``3`` fails the 3rd alloc, ``2,5`` the 2nd
                       and 5th).  Each listed fault fires exactly once —
                       the call counter advances past it.  Tests use
                       the equivalent ``BlockPool(fault_injector=...)``
                       hook directly.  Back-compat alias: ``alloc@N``
                       events in ``REPRO_FAULTS`` compose onto the same
                       injector (both keep firing).
``REPRO_FAULTS``       Generalized multi-seam fault plan (outranks
                       ``ServeConfig.fault_plan``).  Comma-separated
                       spec, grammar ``alloc@N | prefill@N |
                       poison@T[:S] | clock+SEC@T | slow+SEC@T |
                       torn@N | flip@N | fsync@N``:
                       fail the Nth allocator call / Nth admission
                       prefill, NaN-poison one active slot's decode
                       logits at tick T, jump the scheduler clock
                       forward at the start of tick T, inflate tick
                       T's measured duration, tear (half-truncate) or
                       bit-flip the Nth durable disk write, or fail
                       the Nth fsync.  ``faults.FaultPlan
                       .random(seed)`` prints a replayable spec — a
                       failing chaos soak reproduces with
                       ``REPRO_FAULTS=<printed spec>``.
``REPRO_AUDIT_INTERVAL``  Run the invariant auditor every K scheduler
                       ticks (outranks ``ServeConfig.audit_interval``;
                       0 disables).  CI reruns the serve suites at
                       interval 1, so every green path also proves the
                       auditor quiet.
``REPRO_CHECKPOINT_DIR``  Directory for the durable serve plane's
                       on-disk checkpoints + write-ahead request
                       journal (outranks ``ServeConfig
                       .checkpoint_dir``; empty disables durability).
                       Setting it turns on write-ahead journaling of
                       every submit / terminal transition / preemption
                       on the ``PriorityScheduler``.
``REPRO_CHECKPOINT_INTERVAL``  Write a checkpoint every K scheduler
                       ticks (outranks ``ServeConfig
                       .checkpoint_interval``; 0 = no tick-driven
                       checkpoints — the journal still captures every
                       request event, and ``ServeConfig
                       .checkpoint_interval_s`` can drive wall-clock
                       checkpoints independently).
``REPRO_ANALYSIS_BASELINE``  Path of the reprolint suppression
                       baseline consulted by ``python -m repro
                       .analysis`` (default
                       ``reprolint_baseline.json`` at the linted
                       root); see :mod:`repro.analysis`.
``REPRO_TELEMETRY``    Enable the serve-plane telemetry layer
                       (metrics registry families, request-lifecycle
                       tracing, tick/kernel profiling); outranks
                       ``ServeConfig.telemetry``.  Off (the default),
                       metric constructors return the shared no-op
                       metric and tracing records nothing — the stats
                       counter views below count regardless.
``REPRO_TRACE_PATH``   File that ``AsyncFrontend.dump_trace()`` /
                       ``Telemetry.dump_trace()`` additionally writes
                       the canonical-JSON trace export to; outranks
                       ``ServeConfig.trace_path`` (empty: the export
                       is only returned).
=====================  ==================================================

Observability
-------------
``repro.serve.telemetry`` is the one measurement substrate under the
plane.  ``$REPRO_TELEMETRY`` (or ``ServeConfig.telemetry``) turns on
tracing, tick-phase timers, histograms, and gauges; the ``StatsView``
counter families count unconditionally, so the historical ``stats``
dict assertions hold with telemetry off.  ``AsyncFrontend.metrics()``
returns Prometheus text exposition, ``metrics_json()`` the same as a
JSON dict, and ``dump_trace()`` the canonical-JSON event trace —
transport-shaped for the ROADMAP's HTTP frontend (``/metrics``,
``/trace``).

Metric name catalog (every name emitted in code appears here — the
``metricsdocs`` reprolint pass, RL501/RL502, enforces the drift both
ways):

* ``serve_sched_stats`` — priority-scheduler lifecycle counters
  (label ``key``: ticks, admissions, preemptions, shed, timeouts,
  readmissions, readmission_hit_tokens, prefill_faults, quarantined,
  restored, checkpoints, journal_events).
* ``serve_pool_stats`` — block-pool allocator/prefix-sharing counters
  (label ``key``: admissions, lookup_tokens, hit_tokens, cow_copies,
  warm_hit_blocks, warm_reclaims, faults_injected).
* ``serve_checkpoint_stats`` — durable checkpoint/journal store
  counters (label ``key``: checkpoints_written, checkpoint_failures,
  checkpoint_bytes, journal_records, fsync_failures, torn_writes,
  bit_flips, pruned_checkpoints).
* ``serve_fault_fired`` — injected faults fired, by seam (label
  ``key``: alloc, prefill, poison, clock, slow, torn, flip, fsync).
* ``serve_tick_phase_seconds`` — histogram of per-tick phase durations
  (label ``phase``: schedule, prefill, decode, audit).
* ``serve_tick_duration_seconds`` — histogram of whole-tick durations.
* ``serve_batch_occupancy`` — gauge: occupied batch slots at tick end.
* ``serve_pool_free_blocks`` / ``serve_pool_warm_blocks`` /
  ``serve_pool_used_blocks`` — gauges: pool claimable (free + warm),
  warm-subset, and live-referenced block counts at tick end.
* ``serve_request_latency_seconds`` — histogram of per-request latency
  by lifecycle stage (label ``stage``: queue = submit→admit, prefill =
  admit→first token, decode = first token→finish, total).
* ``serve_decode_step_seconds`` — histogram of measured batched decode
  step seconds (``Engine.decode_throughput``).
* ``rsr_dispatch_calls`` — counter of RSR serve-matmul dispatches,
  once per traced shape (labels ``backend`` / ``regime`` /
  ``tile`` as ``BxBLKxN``, ``0x0x0`` for the un-tiled scatter path).
* ``rsr_dispatch_seconds`` — histogram of eagerly measured matmul
  durations (autotune candidates; label ``backend``).

Trace event schema: events are dicts ``{"seq", "ev", "t", ...}`` —
``seq`` a 1-based total order, ``t`` the scheduler's injectable clock
(byte-deterministic exports under a fake/fault clock).  Events and
their extra fields:

* ``submit``      — rid, lane, prompt, max_new (accepted requests);
* ``reject``      — rid, status (terminal at submit);
* ``admit``       — rid, slot, readmit, hit_tokens (warm prefix hit);
* ``first_token`` — rid (prefill finished; sampling began);
* ``decode``      — tick, active (one per batched decode step);
* ``preempt``     — rid, slot, n (cumulative preemptions);
* ``shed`` / ``timeout`` — rid (deadline enforcement);
* ``finish``      — rid, status, tokens (every terminal transition;
  quarantined requests carry status FAILED_NUMERIC).

``telemetry.latency_attribution(events)`` folds a trace into per-lane
queue/prefill/decode/total percentiles (the ``--only telemetry`` bench
section records exactly that).

``AuditError`` failure-mode runbook
-----------------------------------
``audit.audit_scheduler`` re-derives the plane's host-side invariants
from first principles and raises ``AuditError`` at the FIRST tick they
do not hold; ``.invariant`` names the check, ``.state`` carries the dump
(free/warm/refcounts, hash registry, tables, positions, queue/slot
rids).  What a failure implies:

* **I1 refcount conservation** — the pool's refcount vector disagrees
  with the references the slots actually hold: a double free, a missed
  free (leak), or a phantom table entry.  Usually an eviction/rollback
  path that forgot ``_release_blocks`` or released twice.
* **I2 slot references a free/warm block** — use-after-free in the
  making: ``alloc`` can hand that block to another request while a live
  table row still points at it.
* **I3 hash-registry bijection broken** — ``hash→block`` and
  ``block→hash`` disagree, or a warm block is not registered: prefix
  matching would revive the wrong contents (silent wrong tokens).
* **I4 block partition broken** — a block is in two of {free, warm,
  referenced} or in none: the allocator's books no longer cover the
  pool; orphaned blocks leak capacity forever.
* **I5 table row mismatch** — a slot's host block-table row disagrees
  with its held-block list (or the full region is not a clean prefix):
  decode would scatter KV into blocks the allocator thinks are free.
* **I6 position mirror diverged** — the scheduler's host position
  mirror no longer equals the device cache positions: overflow guards
  and block reservations act on wrong offsets.
* **I7 queue/slot overlap** — a request is queued and running at once,
  duplicated, or terminal-but-scheduled: the tick loop would decode a
  corpse or admit twice.
* **I8 overcommit budget exceeded** — the running worst-case demand
  walked past ``overcommit * kv_num_blocks``: the admission gate has a
  hole and preemption storms follow.

Reproducing: every invariant is exercised by the deterministic chaos
paths — run the suspect workload under ``REPRO_AUDIT_INTERVAL=1`` with a
seeded plan, e.g. ``REPRO_FAULTS=$(python -c "from repro.serve.faults
import FaultPlan; print(FaultPlan.random(0).spec)")``, and the auditor
pins the first broken tick instead of letting the corruption surface as
wrong tokens hundreds of ticks later.  ``benchmarks/run.py --only
chaos`` is the canned version: a randomized-but-deterministic fault plan
over mixed traffic with the auditor at interval 1, asserting zero leaks,
no wedges, terminal states for every request, and bitwise token parity
for every request the chaos did not deliberately fail.

Recovery after a crash
----------------------
With a checkpoint directory configured (``ServeConfig.checkpoint_dir``
or ``$REPRO_CHECKPOINT_DIR``), the plane leaves a durable trail:

* ``<dir>/ckpt-<seq:08d>`` — atomic, CRC-checksummed checkpoints of the
  full ``snapshot()`` dict (last ``checkpoint_keep``, newest = highest
  sequence number), written every ``checkpoint_interval`` ticks and/or
  ``checkpoint_interval_s`` seconds;
* ``<dir>/wal-<seq:08d>`` — the write-ahead journal epoch holding every
  submit / terminal / preemption event since checkpoint ``seq``
  published (``wal-0``: since boot).

To force-restore after a kill, construct a FRESH engine with the same
model/serve config and boot from disk::

    fe = AsyncFrontend.recover(engine, dirpath=...)   # or rely on
    # $REPRO_CHECKPOINT_DIR; fe.recovery_report says what happened

or, sync-side, ``durability.recover_scheduler(engine, dirpath=...)``.
The fallback ladder, gentlest first:

1. **Newest valid checkpoint** — restored (``audit_snapshot`` vets the
   decoded dict first), then the journal tail (epochs >= its seq)
   replays: post-checkpoint submits re-enter the queue, terminal events
   settle verbatim with their exact journaled tokens (never recomputed),
   preemption counts are re-applied.
2. **Corrupt newest → older** — a checkpoint failing CRC / structure
   checks is skipped (counted in ``recovery_report
   ["checkpoints_skipped"]``) and the next-older one loads.  Torn
   writes, bit flips, and record-boundary truncation all land here —
   recovery degrades, it does not raise.
3. **No valid checkpoint** — empty plane + full journal replay from
   ``wal-0``.
4. **Refusal** — a VALID checkpoint whose engine fingerprint (model
   name, seq len, batch, block geometry) does not match raises
   ``ValueError``: restoring another engine's KV would be silent
   corruption, so wrong-engine states refuse where corrupt ones fall
   back.

Every recovery runs the I1-I8 ``audit_scheduler`` pass before the plane
is handed back, then writes a fresh checkpoint — rotating onto a clean
journal epoch so a torn pre-crash tail cannot precede post-recovery
events.  Inflight requests resume via the PREEMPTED re-admission path:
their prompt blocks warm-hit from the checkpoint's exported KV, only the
generated tail re-prefills, and greedy tokens continue bitwise where the
crash cut them.  ``benchmarks/run.py --only durability`` is the canned
proof: a seeded kill-at-random-tick soak under torn/flip/fsync disk
faults asserting zero block leaks and bitwise continuity.

The ``REPRO_PAGED_ATTN`` switch
-------------------------------
With paging enabled (``ServeConfig.kv_block_size > 0``) attention has two
scoring backends, resolved at Engine construction (``ServeConfig
.paged_attn``, outranked by the ``$REPRO_PAGED_ATTN`` env var; see
``repro.kernels.paged_attention.select_paged_backend``):

* ``kernel`` (default) — the Pallas paged-attention kernel attends in
  place over the pool blocks through the per-slot block table: one DMA
  pass over the sequence's KV per layer step, online softmax, no dense
  per-slot view.  This is the production serve path and the TPU-memory
  win; it matches ``gather`` to float associativity (~1e-6 f32), with
  token-identical greedy decodes.
* ``gather`` — the dense-gather reference: pool blocks are materialized
  back into the per-slot ``(B, S, ·)`` view and the dense scoring code
  runs.  It is bitwise-equal to the unpaged dense layout by construction.

When to reach for ``gather``: it is the debugging fallback, not a perf
mode.  If paged serving misbehaves, rerun under
``REPRO_PAGED_ATTN=gather`` — if the problem persists, the bug is in the
block tables / allocator / COW plumbing (compare against a dense-layout
engine, which must be bitwise-identical); if the problem disappears, the
bug is in the paged-attention kernel (compare kernel output against the
gather math directly, as tests/test_paged_attn.py does).  ``gather`` is
also the right baseline when measuring what the in-place kernel buys,
e.g. ``benchmarks/run.py --only paged_attn``.
"""
