"""Serving subsystem: the RSR engine, continuous batching, and the
block-paged KV cache.

* ``engine``  — ``Engine`` (chunked prefill + decode over one jitted step)
  and ``BatchScheduler`` (continuous batching with validate-at-submit).
* ``paging``  — ``PagedLayout`` geometry, the host-side ``BlockPool``
  allocator (refcounts, chained prefix hashing, copy-on-write, and the
  LRU warm list of freed-but-still-registered blocks), ``block_hashes``.

The ``REPRO_PAGED_ATTN`` switch
-------------------------------
With paging enabled (``ServeConfig.kv_block_size > 0``) attention has two
scoring backends, resolved at Engine construction (``ServeConfig
.paged_attn``, outranked by the ``$REPRO_PAGED_ATTN`` env var; see
``repro.kernels.paged_attention.select_paged_backend``):

* ``kernel`` (default) — the Pallas paged-attention kernel attends in
  place over the pool blocks through the per-slot block table: one DMA
  pass over the sequence's KV per layer step, online softmax, no dense
  per-slot view.  This is the production serve path and the TPU-memory
  win; it matches ``gather`` to float associativity (~1e-6 f32), with
  token-identical greedy decodes.
* ``gather`` — the dense-gather reference: pool blocks are materialized
  back into the per-slot ``(B, S, ·)`` view and the dense scoring code
  runs.  It is bitwise-equal to the unpaged dense layout by construction.

When to reach for ``gather``: it is the debugging fallback, not a perf
mode.  If paged serving misbehaves, rerun under
``REPRO_PAGED_ATTN=gather`` — if the problem persists, the bug is in the
block tables / allocator / COW plumbing (compare against a dense-layout
engine, which must be bitwise-identical); if the problem disappears, the
bug is in the paged-attention kernel (compare kernel output against the
gather math directly, as tests/test_paged_attn.py does).  ``gather`` is
also the right baseline when measuring what the in-place kernel buys,
e.g. ``benchmarks/run.py --only paged_attn``.
"""
