"""Serving engine: RSR-indexed decode with batched request scheduling.

The engine owns the serve-parameterized tree (RSR codes + packed kernel
streams after offline ``serve_params`` conversion), a pre-allocated KV cache,
and a jitted single-token ``decode_step``.  Every quantized linear inside the
decode graph routes through the backend dispatcher
(``repro.kernels.dispatch``): the Pallas one-hot kernel on TPU (interpret
mode elsewhere), decode-regime tiles from the autotune table (batch ≤ 8 is
the vector-matrix hot path the paper's 5.24× claim targets), scale/bias fused
into the kernel epilogue.  Prefill is a jitted lax.scan of decode steps
(prompt tokens are forced, logits discarded) — simple, exact, and cache-
filling; the large-batch prefill path for throughput serving is the plain
``forward`` (used by the dry-run prefill shapes).

``BatchScheduler`` packs incoming requests into fixed batch slots with
per-slot position tracking — a minimal continuous-batching loop.
``Engine.decode_throughput`` measures steady-state decode tokens/s through
the jitted step — the headline number BENCH_serve.json tracks per PR.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.models import transformer as tfm


class Engine:
    def __init__(self, cfg: ModelConfig, serve_tree: dict, scfg: ServeConfig):
        self.cfg, self.scfg = cfg, scfg
        self.params = serve_tree
        self.batch = scfg.batch_size
        self.cache = tfm.init_cache(cfg, self.batch, scfg.max_seq_len)
        self._decode = jax.jit(
            lambda p, c, t: tfm.decode_step(p, c, t, cfg))

        def _prefill(p, c, toks):                  # toks (B, S)
            def step(c, t):
                logits, c = tfm.decode_step(p, c, t[:, None], cfg)
                return c, logits
            c, logits = jax.lax.scan(step, c, jnp.moveaxis(toks, 1, 0))
            return c, logits[-1]
        self._prefill = jax.jit(_prefill)

    def reset(self):
        self.cache = tfm.init_cache(self.cfg, self.batch,
                                    self.scfg.max_seq_len)

    def prefill(self, tokens: jax.Array):
        """tokens (B, S) -> logits of last position (B, V)."""
        self.cache, logits = self._prefill(self.params, self.cache, tokens)
        return logits

    def sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature)

    def generate(self, prompts: jax.Array, max_new: int, *,
                 key=None) -> np.ndarray:
        """Greedy/temperature generation. prompts (B, S) -> (B, max_new)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits = self.prefill(prompts)
        out = []
        tok = self.sample(logits, key)
        for i in range(max_new):
            out.append(np.asarray(tok))
            logits, self.cache = self._decode(self.params, self.cache,
                                              tok[:, None])
            key, sub = jax.random.split(key)
            tok = self.sample(logits, sub)
        return np.stack(out, axis=1)

    def decode_throughput(self, steps: int = 16, warmup: int = 2) -> dict:
        """Steady-state decode perf of the jitted step (compile excluded).

        Returns {"tokens_per_s", "us_per_step", "batch", "steps"};
        tokens/s counts all batch slots (batch · steps / wall time).
        """
        tok = jnp.ones((self.batch, 1), jnp.int32)
        cache = self.cache
        for _ in range(max(1, warmup)):     # ≥1: compile must stay untimed
            logits, cache = self._decode(self.params, cache, tok)
        logits.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(steps):
            logits, cache = self._decode(self.params, cache, tok)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        return {"tokens_per_s": self.batch * steps / dt,
                "us_per_step": dt / steps * 1e6,
                "batch": self.batch, "steps": steps}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Minimal continuous batching over fixed slots (decode-only packing)."""

    def __init__(self, engine: Engine):
        self.engine = engine
        self.slots: list[Optional[Request]] = [None] * engine.batch
        self.queue: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                self.slots[i] = self.queue.pop(0)

    def run(self) -> list[Request]:
        """Drain the queue (simple generation loop per admission wave)."""
        finished = []
        while self.queue or any(self.slots):
            self._admit()
            active = [s for s in self.slots if s is not None]
            if not active:
                break
            maxlen = max(len(r.prompt) for r in active)
            b = self.engine.batch
            prompts = np.zeros((b, maxlen), np.int32)
            for i, s in enumerate(self.slots):
                if s is not None:
                    prompts[i, -len(s.prompt):] = s.prompt
            self.engine.reset()
            steps = max(r.max_new for r in active)
            toks = self.engine.generate(jnp.asarray(prompts), steps)
            for i, s in enumerate(self.slots):
                if s is not None:
                    s.generated = list(toks[i][:s.max_new])
                    s.done = True
                    finished.append(s)
                    self.slots[i] = None
        return finished
