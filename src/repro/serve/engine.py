"""Serving engine: chunked RSR prefill + continuous-batching decode over a
dense or block-paged KV cache.

``Engine`` owns the serve-parameterized tree (RSR codes + packed kernel
streams after offline ``serve_params`` conversion), the KV cache, and ONE
jitted step — ``tfm.prefill_step`` — that covers both serving regimes.
C == 1 is the classic decode step (batch ≤ 8 rows, the vector-matrix hot
path the paper's 5.24× claim targets); C == prefill_chunk is the chunked-
prefill hot path (B·C flattened rows per quantized linear, prefill tile
regime, scale/bias fused in the kernel epilogue).  The old decode-step
``lax.scan`` prefill survives only as ``prefill_scan`` — the exactness
reference for the parity tests and the BENCH_prefill.json baseline.

Cache layouts (``ServeConfig.kv_block_size``):

* **Dense** (0, the PR-2 layout): every batch slot owns a private
  ``max_seq_len`` row per attention layer; admission requires
  ``prompt + max_new ≤ max_seq_len`` per slot.
* **Paged** (> 0): attention KV lives in a global pool of fixed-size
  blocks (``kv_num_blocks``, +1 trash block absorbing idle-row writes),
  and each slot carries a block *table* mapping logical sequence blocks —
  a full-attention region and, for sliding-window layers, a ring region —
  to physical pool blocks (see ``repro.serve.paging``).  Block tables are
  host-managed: every position-advancing entry point reserves the blocks
  for its known horizon up front (admission reserves ``prompt + max_new``),
  so a decode step never allocates and pool exhaustion can only surface at
  admission, where the scheduler defers instead of failing.  SSM/conv and
  cross-attention states are position-free and stay per-slot.

Paged attention backend (``ServeConfig.paged_attn``, operator override
``REPRO_PAGED_ATTN``, resolved once at Engine construction): ``kernel``
(default) scores decode/prefill queries IN PLACE over the pool blocks with
the Pallas paged-attention kernel — the block table is a scalar-prefetch
operand driving the kernel's KV index maps, softmax accumulates online
across blocks, and no dense per-slot KV view is materialized (1 pass over
O(S) KV per layer step instead of the gather path's read+write+read).
``gather`` restores the PR-3 materialize-then-score path, which is
bitwise-equal to the dense layout — the right debugging reference: a
divergence that reproduces under ``gather`` is a table/allocator bug, one
that only appears under ``kernel`` is a kernel bug (and ``kernel`` vs
``gather`` differ only by float associativity, so greedy tokens match).

Shared-prefix reuse (paged + ``paging.prefix_sharing_supported(cfg)``):
full prompt blocks are content-hashed (chained, so a hit implies the whole
prefix matches); an admission whose leading blocks are already resident
maps them into its table (refcount++) and prefills only the tail — at
least the final prompt token is always recomputed so admission still
yields last-position logits.  When that tail write lands inside a shared
block (prompt length an exact block multiple), the block is copy-on-
written first (``BlockPool.ensure_exclusive`` + ``tfm.copy_pool_block``).
Blocks are freed on eviction; a freed block that still carries a hash
registration moves to the pool's WARM list — matchable by later
admissions at zero prefill cost, reclaimed LRU-first when ``alloc`` runs
dry — so a prefix hit no longer requires a resident holder.

Block-table contract (device side): ``cache['table']`` is ``(batch,
mb_full + mb_ring) int32`` of physical ids; logical full block j of slot b
is ``table[b, j]`` (position p lives in logical block ``p // block_size``
at offset ``p % block_size``), ring block j is ``table[b, mb_full + j]``.
Unassigned entries point at the trash block.  The jitted step treats the
table as read-only data; all assignment happens here on the host.

``BatchScheduler`` is true continuous batching over the fixed slots:
admission validates at ``submit()`` (malformed/oversized requests are
marked failed and returned with the results instead of aborting the run —
the PR-3 bugfix), admits queued requests into free slots when the pool can
take them (strict-FIFO deferral on exhaustion), runs ONE batched decode
step per tick for every active slot, and evicts (frees blocks) on
completion.

``Engine.decode_throughput`` measures steady-state decode tokens/s through
the jitted step (BENCH_serve.json headline); chunked-prefill, scheduler,
and paged/shared-prefix numbers land in BENCH_prefill.json
(``benchmarks/run.py --only prefill`` / ``--only paged``).
"""
from __future__ import annotations

import dataclasses
import enum
import os
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.kernels import paged_attention
from repro.models import transformer as tfm
from repro.serve import faults, paging, telemetry


class Engine:
    def __init__(self, cfg: ModelConfig, serve_tree: dict, scfg: ServeConfig):
        self.cfg, self.scfg = cfg, scfg
        self.params = serve_tree
        self.batch = scfg.batch_size
        # observability plane ($REPRO_TELEMETRY > scfg.telemetry) and the
        # engine's time source: perf_counter standalone, replaced by the
        # scheduler's injectable (possibly fault-skewed) clock when one
        # attaches — decode_throughput then measures on the same clock
        # the plane schedules with
        self.telemetry = telemetry.Telemetry.from_config(scfg)
        self.clock: Callable[[], float] = time.perf_counter
        self.layout = paging.paged_layout(cfg, scfg)
        self.cache = tfm.init_cache(cfg, self.batch, scfg.max_seq_len,
                                    layout=self.layout)
        paged = self.layout is not None
        # paged scoring backend, resolved ONCE (the jitted step closes over
        # it): in-place Pallas kernel (default) vs the dense-gather parity
        # reference.  $REPRO_PAGED_ATTN outranks scfg.paged_attn — set it
        # before constructing the Engine whose step should use it.
        self.paged_attn = (paged_attention.select_paged_backend(
            None, scfg.paged_attn) if paged else None)
        mode = self.paged_attn or "gather"
        # one jitted step for both regimes: (B, C) tokens -> last logits;
        # jax caches a compile per distinct C (decode C=1, the prefill
        # chunk, and at most one ragged remainder per prompt length).
        # The static paged layout is closed over, not an argument.
        layout = self.layout
        self._step = jax.jit(
            lambda p, c, t: tfm.prefill_step(p, c, t, cfg, layout=layout,
                                             paged_attn=mode))
        self._decode = self._step                  # (B, 1): decode == C=1

        def _scan(p, c, toks):                     # toks (B, S)
            def step(c, t):
                logits, c = tfm.prefill_step(p, c, t[:, None], cfg,
                                             layout=layout, paged_attn=mode)
                return c, logits
            c, logits = jax.lax.scan(step, c, jnp.moveaxis(toks, 1, 0))
            return c, logits[-1]
        self._prefill_scan = jax.jit(_scan)
        self._write_slot = jax.jit(
            lambda c, s, i: tfm.update_slot_cache(c, s, i, paged=paged))
        self._copy_block = jax.jit(tfm.copy_pool_block) if paged else None
        # fresh batch-1 slot state for admissions/evictions (immutable —
        # shared freely, never mutated).  In paged mode its dummy 1-block
        # pools are swapped for the live pools by tfm.adopt_pools.
        fresh_layout = (dataclasses.replace(layout, num_blocks=0)
                        if paged else None)
        self._fresh_slot = tfm.init_cache(cfg, 1, scfg.max_seq_len,
                                          layout=fresh_layout)
        # fault-injection seam (repro.serve.faults): set by the scheduler
        # when a FaultPlan is active; consulted at the top of every
        # admission prefill (begin_prefill_job) before any state mutates
        self.fault_plan: Optional[faults.FaultPlan] = None
        # slots whose device table row is masked to trash while a
        # resumable prefill job is in flight (the batched decode step's
        # writes for that row must be absorbed, not land in real blocks)
        self._defer_table: set = set()
        if paged:
            self.pool = paging.BlockPool(
                layout.num_blocks, layout.block_size,
                sharing=paging.prefix_sharing_supported(cfg))
            self._tables = np.full((self.batch, layout.mb_total),
                                   layout.trash_block, np.int32)
            self._slot_blocks: List[List[int]] = [[] for _ in
                                                  range(self.batch)]
            self._full_count = [0] * self.batch     # assigned full blocks
            self._ring_ready = [False] * self.batch

    @property
    def paged(self) -> bool:
        return self.layout is not None

    def reset(self):
        self.cache = tfm.init_cache(self.cfg, self.batch,
                                    self.scfg.max_seq_len, layout=self.layout)
        if self.paged:
            self.pool = paging.BlockPool(
                self.layout.num_blocks, self.layout.block_size,
                sharing=self.pool.sharing,
                fault_injector=self.pool.fault_injector)
            self._tables[:] = self.layout.trash_block
            self._slot_blocks = [[] for _ in range(self.batch)]
            self._full_count = [0] * self.batch
            self._ring_ready = [False] * self.batch
        self._defer_table = set()

    # -- paged block-table management (host side) --------------------------

    def _push_table(self):
        t = self._tables
        if self._defer_table:
            # mid-prefill-job slots present as trash rows to the batched
            # step; the job's own batch-1 sub carries the real row
            t = t.copy()
            for i in self._defer_table:
                t[i, :] = self.layout.trash_block
        self.cache = {**self.cache, "table": jnp.asarray(t)}

    def _release_blocks(self, slot: int):
        for bid in self._slot_blocks[slot]:
            self.pool.free(bid)
        self._slot_blocks[slot] = []
        self._full_count[slot] = 0
        self._ring_ready[slot] = False
        self._defer_table.discard(slot)
        self._tables[slot, :] = self.layout.trash_block

    def _reserve(self, slot: int, upto: int):
        """Assign blocks so slot's table covers positions [0, upto) (full
        region) and the whole ring region.  Raises BlockPoolExhausted when
        the pool cannot satisfy it — scheduler admission checks first."""
        lay = self.layout
        if lay.mb_ring and not self._ring_ready[slot]:
            ring = self.pool.alloc(lay.mb_ring)
            self._tables[slot, lay.mb_full:] = ring
            self._slot_blocks[slot].extend(ring)
            self._ring_ready[slot] = True
        need = lay.blocks_for(upto)
        cur = self._full_count[slot]
        if need > cur:
            fresh = self.pool.alloc(need - cur)
            self._tables[slot, cur:need] = fresh
            self._slot_blocks[slot].extend(fresh)
            self._full_count[slot] = need

    def _admission_plan(self, prompt: np.ndarray, max_new: int, *,
                        lazy: bool = False):
        """(hashes, hits, tail_start, cow, demand) for admitting `prompt`
        with `max_new` reserved decode tokens, WITHOUT mutating allocator
        state (the hits are not claimed yet).  ``demand`` counts the blocks
        the admission takes OUT of the pool's claimable set: ring blocks +
        non-shared full blocks (incl. one decode-headroom block, see
        ``PagedLayout.blocks_for_admission``) + the copy-on-write
        replacement when the tail write would land in a shared block + any
        WARM hits (an evicted-but-unreclaimed hit still counts toward
        ``free_count`` until taking it revives it).

        ``lazy=True`` plans a LAZY admission (the priority request plane):
        only the prompt blocks plus one headroom block are demanded up
        front — the decode horizon is extended block-by-block via
        ``reserve_tokens`` as positions grow, so ``max_new`` does not enter
        the demand (it still bounds the caller's worst case elsewhere)."""
        lay = self.layout
        L = len(prompt)
        hashes = (paging.block_hashes(prompt, lay.block_size)
                  if self.pool.sharing else [])
        hits = self.pool.match_prefix(hashes)
        shared_tok = len(hits) * lay.block_size
        tail_start = min(shared_tok, L - 1)
        cow = tail_start < shared_tok          # tail writes a shared block
        # ... but a WARM last hit revives to refcount 1, so ensure_exclusive
        # will NOT copy — charging it anyway would overstate demand and can
        # deadlock a request whose worst case exactly fills the pool
        cow_charge = 1 if (cow and not self.pool.is_warm(hits[-1])) else 0
        total = lay.blocks_for_admission(L, 0 if lazy else max_new)
        warm = sum(1 for bid in hits if self.pool.is_warm(bid))
        demand = (total - len(hits)) + cow_charge + lay.mb_ring + warm
        return hashes, hits, tail_start, cow, demand

    def can_admit(self, prompt, max_new: int, *, lazy: bool = False):
        """Pool-capacity check for one admission (no allocator mutation).
        Returns the admission plan when it fits (truthy; pass it to
        ``prefill_into(..., plan=...)`` to avoid re-hashing the prompt),
        ``None`` when the pool cannot take it yet, ``True`` when dense.
        ``lazy`` plans prompt+headroom only (see ``_admission_plan``)."""
        if not self.paged:
            return True
        prompt = np.asarray(prompt)
        plan = self._admission_plan(prompt, max_new, lazy=lazy)
        return plan if plan[-1] <= self.pool.free_count else None

    def worst_case_blocks(self, prompt_len: int, max_new: int) -> int:
        """Blocks this request needs resident at its FINAL position (no
        sharing assumed) — the quantity the priority plane's overcommit
        budget sums over running requests.  0 when dense."""
        if not self.paged:
            return 0
        lay = self.layout
        return lay.mb_ring + lay.blocks_for(prompt_len + max_new)

    def reserve_tokens(self, slot: int, upto: int) -> bool:
        """Lazy-mode decode-horizon extension: grow ``slot``'s block table
        to cover positions [0, upto) (and the ring region).  Returns False
        instead of raising when the pool cannot satisfy it — the caller
        (the priority plane) preempts a victim and retries.  Any partial
        progress (e.g. ring blocks landed, full blocks did not) is kept:
        reservation is monotone and the blocks are released on eviction."""
        if not self.paged:
            return True
        lay = self.layout
        if ((self._ring_ready[slot] or not lay.mb_ring)
                and lay.blocks_for(upto) <= self._full_count[slot]):
            return True                      # already covered: no table push
        try:
            self._reserve(slot, upto)
        except paging.BlockPoolExhausted:
            self._push_table()               # partial ring alloc may exist
            return False
        self._push_table()
        return True

    # -- capacity ----------------------------------------------------------

    def free_slot(self, slot: int):
        """Zero slot's cache rows + position (eviction / pre-admission);
        paged mode also releases the slot's blocks (refcount--, shared
        blocks stay resident while other holders live)."""
        sub = self._fresh_sub()
        self.cache = self._write_slot(self.cache, sub, jnp.int32(slot))
        if self.paged:
            self._release_blocks(slot)
            self._push_table()

    def _fresh_sub(self):
        if not self.paged:
            return self._fresh_slot
        return tfm.adopt_pools(self._fresh_slot, self.cache)

    def _check_capacity(self, start: int, new_tokens: int, what: str):
        """Cache writes past max_seq_len are out-of-range scatters — XLA
        DROPS them silently and the causal mask would then attend stale
        rows, so every position-advancing entry point validates first."""
        end = start + new_tokens
        if end > self.scfg.max_seq_len:
            raise ValueError(
                f"{what} would advance slot positions to {end} > "
                f"max_seq_len={self.scfg.max_seq_len} (start={start}); "
                f"reset()/free_slot() or raise max_seq_len")

    def _reserve_all(self, upto: int):
        if not self.paged:
            return
        for i in range(self.batch):
            self._reserve(i, upto)
        self._push_table()

    # -- prefill / decode --------------------------------------------------

    def prefill(self, tokens: jax.Array, *, chunk: Optional[int] = None,
                start: Optional[int] = None):
        """Chunked whole-batch prefill: tokens (B, S) -> last logits (B, V).

        Each chunk is one ``_step`` call — B·chunk flattened rows per
        quantized linear (the prefill tile regime) instead of the scan
        reference's S sequential single-token launches.  ``start`` is the
        caller-known max slot position (skips a per-call device sync for
        the capacity check — e.g. 0 right after reset()).
        """
        if tokens.shape[1] == 0:
            raise ValueError("prefill of an empty prompt (S == 0)")
        if start is None:
            start = int(jax.device_get(jnp.max(self.cache["pos"])))
        self._check_capacity(start, tokens.shape[1], "prefill")
        self._reserve_all(start + tokens.shape[1])
        chunk = int(chunk or self.scfg.prefill_chunk)
        logits = None
        for off in range(0, tokens.shape[1], chunk):
            logits, self.cache = self._step(self.params, self.cache,
                                            tokens[:, off:off + chunk])
        return logits

    def prefill_scan(self, tokens: jax.Array):
        """Reference prefill: jitted lax.scan of single-token decode steps
        (the pre-chunking path; parity baseline for tests/BENCH_prefill)."""
        if self.paged:
            start = int(jax.device_get(jnp.max(self.cache["pos"])))
            self._reserve_all(start + tokens.shape[1])
        self.cache, logits = self._prefill_scan(self.params, self.cache,
                                                tokens)
        return logits

    def begin_prefill_job(self, slot: int, prompt, *, reserve: int = 0,
                          plan=None) -> "PrefillJob":
        """Start a RESUMABLE per-slot admission prefill (the allocator
        half of ``prefill_into``, which is now ``begin`` + ``step(all)`` +
        ``finish``).  All block claiming happens here — previous blocks
        released, shared-prefix hits claimed (tail/COW derived from the
        CLAIMED hits, not the plan: if registrations changed since
        ``can_admit``, the claim is the truth), blocks reserved out to
        ``len(prompt) + reserve`` plus decode headroom — so pool
        exhaustion can only surface now, never mid-job.  Until the job
        finishes, the slot's DEVICE table row stays masked to trash (the
        batched decode step may run between job steps; its writes for
        this row are absorbed) while the job's batch-1 sub carries the
        real row.  ``plan`` accepts the admission plan a ``can_admit``
        call just returned (skips re-hashing); it is only trusted while
        the slot holds no blocks.  Raises ``faults.PrefillFault`` when an
        active FaultPlan schedules this admission to fail — before any
        state mutates, so the caller's retry needs no rollback beyond
        ``free_slot``."""
        if self.fault_plan is not None and self.fault_plan.take_prefill():
            raise faults.PrefillFault(
                f"injected: admission prefill into slot {slot}")
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            raise ValueError(f"prefill_into(slot={slot}): empty prompt")
        L = int(prompt.shape[0])
        self._check_capacity(0, L + max(0, reserve),
                             f"prefill_into(slot={slot})")
        tail_start = 0
        hashes: list = []
        n_shared = 0
        if self.paged:
            lay = self.layout
            if plan is None or self._slot_blocks[slot]:
                self._release_blocks(slot)
                plan = self._admission_plan(prompt, max(0, reserve))
            hashes = plan[0]                   # prompt-only: never stale
            hits = self.pool.take_prefix(hashes)   # claim (incref) the hits
            n_shared = len(hits)
            shared_tok = n_shared * lay.block_size
            tail_start = min(shared_tok, L - 1)
            cow = tail_start < shared_tok
            self._tables[slot, :n_shared] = hits
            self._slot_blocks[slot].extend(hits)
            self._full_count[slot] = n_shared
            if cow:
                old = hits[-1]
                new, copied = self.pool.ensure_exclusive(old)
                if copied:
                    self.cache = self._copy_block(
                        self.cache, jnp.int32(old), jnp.int32(new))
                    self._tables[slot, n_shared - 1] = new
                    self._slot_blocks[slot][-1] = new
            self._reserve(slot, lay.blocks_for_admission(
                L, max(0, reserve)) * lay.block_size)
            self._defer_table.add(slot)
            self._push_table()
        # the slot's MAIN-cache row keeps taking batched decode steps
        # between job steps (absorbed: trash-masked table row / overwritten
        # at finish), and an idle row's position may hold garbage decode
        # increments — restart it at 0 so the scheduler's host mirror can
        # track it exactly (audit I6) and it cannot creep toward the
        # max_seq_len overflow guard while the job is parked
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)
        toks = jnp.asarray(prompt[tail_start:])[None, :]
        sub = self._fresh_sub()
        if self.paged:
            sub = {**sub,
                   "table": jnp.asarray(self._tables[slot:slot + 1]),
                   "pos": jnp.full((1,), tail_start, jnp.int32)}
        return PrefillJob(self, slot, toks, sub, hashes, n_shared, L)

    def step_prefill_job(self, job: "PrefillJob", max_tokens: int = 0, *,
                         chunk: Optional[int] = None) -> int:
        """Run up to ``max_tokens`` of the job's remaining tail tokens
        (0 = all of them) in ``prefill_chunk``-sized steps; returns the
        token count actually run.  Paged mode refreshes the job's pool
        view first (other slots decoded between job steps) and commits
        the job's pool writes back after, so interleaved batched decodes
        and multiple concurrent jobs all build on one pool history."""
        chunk = int(chunk or self.scfg.prefill_chunk)
        budget = (job.remaining if max_tokens <= 0
                  else min(int(max_tokens), job.remaining))
        if budget <= 0:
            return 0
        sub = job._sub
        if self.paged:
            sub = tfm.adopt_pools(sub, self.cache)
        end = job._off + budget
        while job._off < end:
            take = min(chunk, end - job._off)
            job.logits, sub = self._step(
                self.params, sub, job._toks[:, job._off:job._off + take])
            job._off += take
        job._sub = sub
        if self.paged:
            self.cache = tfm.adopt_pools(self.cache, sub)
        return budget

    def finish_prefill_job(self, job: "PrefillJob"):
        """Commit a completed job: write the sub back into the slot's row
        (unmasking the device table row), publish the freshly written
        full prompt blocks for sharing, return last logits (V,)."""
        if not job.done:
            raise RuntimeError(
                f"finish_prefill_job(slot={job.slot}): {job.remaining} "
                f"tail tokens still pending")
        self._defer_table.discard(job.slot)
        self.cache = self._write_slot(self.cache, job._sub,
                                      jnp.int32(job.slot))
        if self.paged and self.pool.sharing:
            for j in range(job._n_shared, job._len // self.layout.block_size):
                self.pool.register(int(self._tables[job.slot, j]),
                                   job._hashes[j])
        return job.logits[0]

    def cancel_prefill_job(self, job: "PrefillJob") -> None:
        """Abandon a mid-flight job (timeout / shutdown): drop the held
        sub and unmask the table row.  The caller owns the slot cleanup
        (``free_slot`` releases the blocks the job claimed)."""
        self._defer_table.discard(job.slot)
        job._off = job._toks.shape[1]
        job._sub = None

    def prefill_into(self, slot: int, prompt, *, chunk: Optional[int] = None,
                     reserve: int = 0, plan=None):
        """Per-slot admission prefill of a 1-D prompt into slot's rows from
        a fresh state; every other slot is untouched (they can sit mid-
        decode).  Returns last logits (V,).

        Paged mode additionally: releases the slot's previous blocks, maps
        resident shared-prefix blocks (prefilling only the tail — always at
        least the final prompt token, so logits exist; a tail write into a
        still-shared block copy-on-writes it first), reserves blocks out to
        ``len(prompt) + reserve`` — plus one block of decode headroom —
        so the subsequent ``reserve`` decode steps never allocate, and
        registers the freshly written full prompt blocks for future
        sharing.  Decoding the slot beyond ``reserve`` (and the headroom
        block) without re-reserving is a contract violation: those writes
        land in the trash block.  ``plan`` accepts the admission plan a
        ``can_admit`` call just returned (skips re-hashing the prompt);
        it is only trusted while the slot holds no blocks.

        This is the one-shot form of the resumable prefill-job triple
        (``begin_prefill_job`` / ``step_prefill_job`` /
        ``finish_prefill_job``) the priority plane uses to budget
        re-prefill work per tick.
        """
        job = self.begin_prefill_job(slot, prompt, reserve=reserve,
                                     plan=plan)
        self.step_prefill_job(job, 0, chunk=chunk)
        return self.finish_prefill_job(job)

    # -- crash-safe snapshot support (repro.serve.frontend) ----------------

    def _pool_leaf_paths(self):
        """((section, axis) pairs — pool leaves live under each with the
        physical-block axis at ``axis``)."""
        return (("head", 0), ("blocks", 1), ("tail", 0))

    def export_blocks(self, bids: List[int]) -> dict:
        """Device → host KV contents of the given pool blocks, keyed by
        ``section + keystr(path)`` per pool leaf (numpy arrays with the
        selected blocks along each leaf's block axis).  The snapshot half
        of crash-safe restore: only hash-registered (full prompt) blocks
        are worth exporting — decode tails re-prefill on resume."""
        out: dict = {}
        if not self.paged or not bids:
            return out
        idx = jnp.asarray(np.asarray(bids, np.int32))
        tmap = jax.tree_util.tree_map_with_path
        for section, axis in self._pool_leaf_paths():
            def grab(path, a, _section=section, _axis=axis):
                if tfm._is_pool(path):
                    out[_section + jax.tree_util.keystr(path)] = np.asarray(
                        jax.device_get(jnp.take(a, idx, axis=_axis)))
                return a
            tmap(grab, self.cache[section])
        return out

    def import_blocks(self, bids: List[int], kv: dict) -> None:
        """Host → device upload of ``export_blocks`` output into the given
        pool block ids (same order as the export's).  The engine must be
        paged; the caller (scheduler restore) seats the allocator state
        (``BlockPool.seed_warm``) to match."""
        if not self.paged or not bids:
            return
        idx = jnp.asarray(np.asarray(bids, np.int32))
        tmap = jax.tree_util.tree_map_with_path
        cache = dict(self.cache)
        for section, axis in self._pool_leaf_paths():
            def put(path, a, _section=section, _axis=axis):
                key = _section + jax.tree_util.keystr(path)
                if not tfm._is_pool(path) or key not in kv:
                    return a
                upd = jnp.asarray(kv[key], a.dtype)
                return (a.at[idx].set(upd) if _axis == 0
                        else a.at[:, idx].set(upd))
            cache[section] = tmap(put, self.cache[section])
        self.cache = cache

    def sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature)

    def generate(self, prompts: jax.Array, max_new: int, *,
                 key=None) -> np.ndarray:
        """Greedy/temperature generation. prompts (B, S) -> (B, max_new).

        ``max_new == 0`` returns shape (B, 0) — the prefill still runs (the
        cache is left warm) but no token is emitted; ``max_new == 1`` emits
        exactly the prefill-sampled token with no decode step.
        """
        key = key if key is not None else jax.random.PRNGKey(0)
        start = int(jax.device_get(jnp.max(self.cache["pos"])))
        self._check_capacity(start, prompts.shape[1] + max_new, "generate")
        self._reserve_all(start + prompts.shape[1] + max_new)
        logits = self.prefill(prompts, start=start)
        if max_new <= 0:
            return np.zeros((prompts.shape[0], 0), np.int32)
        tok = self.sample(logits, key)
        out = [np.asarray(tok)]
        # token 0 comes from the prefill logits, so only max_new - 1 decode
        # steps are needed — no trailing decode whose sample is discarded
        for _ in range(max_new - 1):
            logits, self.cache = self._decode(self.params, self.cache,
                                              tok[:, None])
            key, sub = jax.random.split(key)
            tok = self.sample(logits, sub)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)

    def decode_throughput(self, steps: int = 16, warmup: int = 2) -> dict:
        """Steady-state decode perf of the jitted step (compile excluded).

        Returns {"tokens_per_s", "us_per_step", "batch", "steps"};
        tokens/s counts all batch slots (batch · steps / wall time).
        The measurement advances a LOCAL cache (the engine's committed
        state is untouched), so slot positions are validated up front:
        silently wrapping past max_seq_len would time scatter writes that
        never land (out-of-range updates are dropped) and corrupt the
        number.  Paged mode reserves blocks for the measured horizon (they
        stay assigned to the slots; reset()/free_slot() reclaims them).
        """
        start = int(jax.device_get(jnp.max(self.cache["pos"])))
        self._check_capacity(start, max(1, warmup) + steps,
                             "decode_throughput")
        self._reserve_all(start + max(1, warmup) + steps)
        tok = jnp.ones((self.batch, 1), jnp.int32)
        cache = self.cache
        clock = self.clock      # injectable: the scheduler's (fault) clock
        for _ in range(max(1, warmup)):     # ≥1: compile must stay untimed
            logits, cache = self._decode(self.params, cache, tok)
        logits.block_until_ready()
        t0 = clock()
        for _ in range(steps):
            logits, cache = self._decode(self.params, cache, tok)
        logits.block_until_ready()
        dt = clock() - t0
        if dt <= 0:             # frozen injected clock: keep the math finite
            dt = 1e-12
        if self.telemetry.enabled and steps > 0:
            self.telemetry.histogram(
                "serve_decode_step_seconds",
                "Measured batched decode step seconds "
                "(decode_throughput).").observe(dt / steps)
        return {"tokens_per_s": self.batch * steps / dt,
                "us_per_step": dt / steps * 1e6,
                "batch": self.batch, "steps": steps}


class PrefillJob:
    """A resumable per-slot admission prefill (see
    ``Engine.begin_prefill_job``).  All blocks are claimed at ``begin``;
    the tail tokens then run in budgeted rounds (``step_prefill_job``)
    across scheduler ticks, the batch-1 sub cache held here in between —
    per-slot state never touches the batched cache until ``finish``,
    and the slot's device table row stays masked to trash so interleaved
    batched decode steps cannot write into the job's blocks."""

    def __init__(self, engine: "Engine", slot: int, toks, sub, hashes,
                 n_shared: int, length: int):
        self.slot = slot
        self.logits = None            # last-step logits (1, V) once run
        self._toks = toks             # (1, T) tail tokens
        self._off = 0                 # tail tokens already run
        self._sub = sub               # held batch-1 cache
        self._hashes = hashes
        self._n_shared = n_shared
        self._len = length            # full sequence length

    @property
    def remaining(self) -> int:
        return int(self._toks.shape[1]) - self._off

    @property
    def done(self) -> bool:
        return self.remaining <= 0


class RequestStatus(enum.Enum):
    """Machine-readable request state.  Terminal states carry the outcome a
    client can branch on without parsing ``Request.error`` (which stays the
    human-readable detail string):

    * ``OK`` — completed normally (``generated`` holds ``max_new`` tokens).
    * ``REJECTED_VALIDATION`` — malformed at ``submit()`` (shape, max_new,
      ``prompt + max_new > max_seq_len``); never entered the queue.
    * ``REJECTED_CAPACITY`` — valid but can never fit this engine (worst-
      case block demand exceeds the whole pool); never entered the queue.
    * ``TIMEOUT`` — deadline enforcement fired: either shed at admission
      (deadline expired / hopeless while queued; ``generated`` empty) or
      cut off mid-decode (``generated`` holds the partial output).  A
      graceful terminal state, not an exception.
    * ``FAILED_NUMERIC`` — the numeric quarantine fired: this request's
      decode logits went non-finite (NaN/inf), so it was cut off with its
      partial output and its blocks freed while the rest of the batch
      continued bitwise-unchanged (greedy argmax rows are independent).
      A poisoned request must never silently emit garbage tokens.

    Transient states: ``QUEUED`` (accepted, waiting), ``RUNNING`` (in a
    batch slot), ``PREEMPTED`` (evicted mid-decode by the priority plane to
    free blocks; back in the queue, re-admission continues the decode).
    """
    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    PREEMPTED = "PREEMPTED"
    OK = "OK"
    REJECTED_VALIDATION = "REJECTED_VALIDATION"
    REJECTED_CAPACITY = "REJECTED_CAPACITY"
    TIMEOUT = "TIMEOUT"
    FAILED_NUMERIC = "FAILED_NUMERIC"

    @property
    def terminal(self) -> bool:
        return self in (RequestStatus.OK, RequestStatus.REJECTED_VALIDATION,
                        RequestStatus.REJECTED_CAPACITY,
                        RequestStatus.TIMEOUT, RequestStatus.FAILED_NUMERIC)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    priority: int = 0                 # lane; 0 is the most urgent
    deadline_s: Optional[float] = None  # completion budget in seconds from
                                        # arrival (EDF ordering + TIMEOUT
                                        # enforcement); None = no deadline
    arrival: Optional[float] = None   # scheduler clock at submit() (set by
                                      # submit() when None)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None       # human-readable detail; `status` is
                                      # the machine-readable reason
    status: RequestStatus = RequestStatus.QUEUED
    preemptions: int = 0              # times evicted mid-decode
    completed_at: Optional[float] = None
    on_token: Optional[Callable[["Request", int], None]] = \
        dataclasses.field(default=None, repr=False)  # per-token streaming
                                                     # callback (frontend)

    @property
    def deadline(self) -> Optional[float]:
        """Absolute deadline on the scheduler clock (None = none)."""
        if self.deadline_s is None or self.arrival is None:
            return None
        return self.arrival + self.deadline_s

    def to_json(self) -> dict:
        """Deep, JSON-serializable copy of the request's durable fields
        (the snapshot/journal wire form).  Live wiring is stripped, not
        carried: ``on_token`` callbacks (and the frontend's futures,
        which never live on the Request) cannot survive a process death
        — ``streaming`` flags that the original had a callback so a
        recovered client knows the stream is gone."""
        return {"rid": int(self.rid),
                "prompt": np.asarray(self.prompt, np.int32).tolist(),
                "max_new": int(self.max_new),
                "priority": int(self.priority),
                "deadline_s": self.deadline_s,
                "arrival": self.arrival,
                "generated": [int(t) for t in self.generated],
                "preemptions": int(self.preemptions),
                "status": self.status.value,
                "error": self.error,
                "completed_at": self.completed_at,
                "streaming": self.on_token is not None}

    @classmethod
    def from_json(cls, d: dict) -> "Request":
        """Inverse of :meth:`to_json` (``streaming`` is informational —
        no callback is reattached).  ``done`` derives from the status'
        terminality, so replayed terminal events round-trip exact."""
        req = cls(rid=int(d["rid"]),
                  prompt=np.asarray(d["prompt"], np.int32),
                  max_new=int(d["max_new"]),
                  priority=int(d.get("priority", 0)),
                  deadline_s=d.get("deadline_s"),
                  arrival=d.get("arrival"))
        req.generated = [int(t) for t in d.get("generated", [])]
        req.preemptions = int(d.get("preemptions", 0))
        req.status = RequestStatus(d.get("status", "QUEUED"))
        req.error = d.get("error")
        req.completed_at = d.get("completed_at")
        req.done = req.status.terminal
        return req


class BatchScheduler:
    """Continuous batching over the engine's fixed slots.

    Each loop tick admits queued requests into free slots (per-slot chunked
    prefill at the request's TRUE length — no left padding, no reset of the
    other slots) and then runs ONE batched decode step for every slot.
    Completed requests are evicted immediately, freeing their slot (and, in
    paged mode, their blocks) for the next admission — no head-of-line
    blocking on the longest request.

    Robustness contract: ``submit()`` validates the request (shape,
    ``prompt + max_new ≤ max_seq_len``, worst-case block demand ≤ pool) —
    an invalid request is marked ``done`` with a machine-readable terminal
    ``status`` (``REJECTED_VALIDATION`` / ``REJECTED_CAPACITY``; ``error``
    keeps the detail string) and returned from ``run()`` alongside the
    completed ones instead of raising mid-drain and abandoning the queue.
    Paged admission additionally defers (strict FIFO) while the pool is too
    full, resuming as evictions free blocks; because every accepted
    request's worst-case demand fits an empty pool, the drain always makes
    progress.

    The drain is structured as ``tick()`` steps (one admission pass + one
    batched decode step, returning the tick's ``(request, token)`` stream
    events) so the asyncio request plane (``repro.serve.frontend``) can
    interleave scheduling with an event loop; ``run()`` is the synchronous
    drain over ``tick()``.  This base class is strict-FIFO with eager
    worst-case block reservation; ``frontend.PriorityScheduler`` overrides
    the policy hooks for priority lanes, deadlines, lazy allocation, and
    preemption.
    """

    def __init__(self, engine: Engine, *, clock=None):
        self.engine = engine
        self.clock = clock if clock is not None else time.monotonic
        self.telemetry = engine.telemetry
        engine.clock = self.clock      # one time source for plane + engine
        self.slots: list[Optional[Request]] = [None] * engine.batch
        self.queue: list[Request] = []
        self.rejected: list[Request] = []
        self._next_tok = np.zeros((engine.batch,), np.int32)
        # host mirror of per-slot cache positions: overflow guard without a
        # device sync per tick
        self._pos = [0] * engine.batch
        self._key = jax.random.PRNGKey(0)
        self._tick_no = 0             # 1-based inside tick() (fault plans
                                      # and the auditor key off it)
        env_ai = os.environ.get("REPRO_AUDIT_INTERVAL", "").strip()
        self.audit_interval = (int(env_ai) if env_ai else
                               int(getattr(engine.scfg, "audit_interval",
                                           0)))

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    def _trace(self, ev: str, **fields) -> None:
        """Record one lifecycle trace event (no-op unless telemetry is
        enabled; the timestamp is THIS scheduler's injectable clock, so
        traces are deterministic under fake/fault clocks)."""
        tel = self.telemetry
        if tel.enabled:
            tel.trace.event(ev, self.clock(), **fields)

    def submit(self, req: Request):
        """Validate and enqueue.  Invalid requests never enter the queue:
        they are marked failed (``req.status`` machine-readable, ``req.
        error`` the detail) and surface in ``run()``'s results — the PR-3
        regression fix (an oversized request used to raise mid-``run()``,
        abandoning all queued and in-flight work)."""
        if req.arrival is None:
            req.arrival = self.clock()
        verdict = self._validate(req)
        if verdict is not None:
            req.status, req.error = verdict
            req.done = True
            req.completed_at = self.clock()
            self.rejected.append(req)
            self._trace("reject", rid=req.rid, status=req.status.name)
            return
        req.status = RequestStatus.QUEUED
        self.queue.append(req)
        self._trace("submit", rid=req.rid, lane=req.priority,
                    prompt=len(req.prompt), max_new=req.max_new)

    def _validate(self, req: Request):
        """None when admissible, else (terminal RequestStatus, detail)."""
        eng = self.engine
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            return (RequestStatus.REJECTED_VALIDATION,
                    f"request {req.rid}: prompt must be 1-D and non-empty")
        if req.max_new < 1:
            return (RequestStatus.REJECTED_VALIDATION,
                    f"request {req.rid}: max_new={req.max_new} < 1")
        need = prompt.shape[0] + req.max_new
        if need > eng.scfg.max_seq_len:
            return (RequestStatus.REJECTED_VALIDATION,
                    f"request {req.rid}: prompt+max_new={need} exceeds "
                    f"max_seq_len={eng.scfg.max_seq_len}")
        if eng.paged:
            # worst case = admission against an EMPTY pool: no shared hits
            # (hence no COW either), every block fresh.  If this fits, the
            # strict-FIFO drain can always make progress.
            lay = eng.layout
            worst = lay.mb_ring + lay.blocks_for_admission(
                prompt.shape[0], req.max_new)
            if worst > lay.num_blocks:
                return (RequestStatus.REJECTED_CAPACITY,
                        f"request {req.rid}: needs {worst} blocks "
                        f"(pool={lay.num_blocks})")
        return None

    # -- internals ---------------------------------------------------------

    def _sample(self, logits) -> np.ndarray:        # (B, V) -> (B,)
        self._key, sub = jax.random.split(self._key)
        return np.asarray(self.engine.sample(logits, sub))

    def _finish(self, i: int,
                status: RequestStatus = RequestStatus.OK) -> Request:
        req = self.slots[i]
        req.done = True
        req.status = status
        req.completed_at = self.clock()
        self.slots[i] = None
        self.engine.free_slot(i)
        self._pos[i] = 0
        if self.telemetry.enabled:
            self._trace("finish", rid=req.rid, status=status.name,
                        tokens=len(req.generated))
            self._observe_latency(req)
        return req

    def _observe_latency(self, req: Request) -> None:
        """Per-stage latency attribution into
        ``serve_request_latency_seconds{stage}`` — stage boundary
        timestamps (``_t_admit`` / ``_t_first``) are stamped
        opportunistically while telemetry is enabled."""
        hist = self.telemetry.histogram(
            "serve_request_latency_seconds",
            "Request latency by lifecycle stage.", ("stage",))
        t_sub = req.arrival
        t_adm = getattr(req, "_t_admit", None)
        t_tok = getattr(req, "_t_first", None)
        t_fin = req.completed_at
        if t_sub is not None and t_adm is not None:
            hist.labels(stage="queue").observe(t_adm - t_sub)
        if t_adm is not None and t_tok is not None:
            hist.labels(stage="prefill").observe(t_tok - t_adm)
        if t_tok is not None and t_fin is not None:
            hist.labels(stage="decode").observe(t_fin - t_tok)
        if t_sub is not None and t_fin is not None:
            hist.labels(stage="total").observe(t_fin - t_sub)

    def _emit(self, req: Request, tok: int, events: list):
        """Record one generated token as a stream event + fire the
        request's streaming callback (if any)."""
        events.append((req, tok))
        if self.telemetry.enabled and len(req.generated) == 1:
            t = self.clock()
            req._t_first = t
            self.telemetry.trace.event("first_token", t, rid=req.rid)
        if req.on_token is not None:
            req.on_token(req, tok)

    def _maybe_audit(self):
        """Run the invariant auditor every ``audit_interval`` ticks
        (0 = never).  Called at the end of every tick, when the state
        machine claims to be consistent; raises ``audit.AuditError``
        the first tick it is not."""
        if self.audit_interval > 0 and self._tick_no % self.audit_interval == 0:
            from repro.serve import audit     # lazy: avoids import cycle
            audit.audit_scheduler(self)

    def _decoding_slots(self) -> list[int]:
        """Slots taking part in this tick's batched decode step — every
        occupied slot here; the priority plane excludes slots whose
        admission prefill is still mid-job."""
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _filter_logits(self, logits, active: list[int]):
        """Decode-logits hook between the jitted step and sampling; the
        priority plane's fault plan poisons a row here.  Base: identity."""
        return logits

    def _admit(self, finished: list, events: list) -> bool:
        """Admit queued requests into free slots; returns True if any
        admission happened.  Strict FIFO: when the pool cannot take the
        queue head yet, admission stops (it resumes as evictions free
        blocks) rather than starving it with later, smaller requests."""
        eng = self.engine
        progressed = False
        for i in range(eng.batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            plan = eng.can_admit(req.prompt, req.max_new)
            if plan is None:
                break
            self.queue.pop(0)
            logits = eng.prefill_into(i, req.prompt, reserve=req.max_new,
                                      plan=None if plan is True else plan)
            progressed = True
            req.status = RequestStatus.RUNNING
            if self.telemetry.enabled:
                t = self.clock()
                req._t_admit = t
                self.telemetry.trace.event("admit", t, rid=req.rid, slot=i,
                                           readmit=False, hit_tokens=0)
            tok = int(self._sample(logits[None, :])[0])
            req.generated.append(tok)
            self._emit(req, tok, events)
            self._pos[i] = len(req.prompt)
            self.slots[i] = req
            if len(req.generated) >= req.max_new:
                finished.append(self._finish(i))
            else:
                self._next_tok[i] = tok
        return progressed

    def _decode_once(self, finished: list, events: list):
        """One batched decode step over every slot: recycle/overflow-check
        idle rows, run the jitted step, quarantine rows with non-finite
        logits (FAILED_NUMERIC — the poisoned request keeps its partial
        output, its blocks free, every other row is bitwise-unchanged
        because greedy argmax is row-independent), distribute sampled
        tokens, evict completed requests."""
        eng = self.engine
        max_seq = eng.scfg.max_seq_len
        active = self._decoding_slots()
        self._trace("decode", tick=self._tick_no, active=len(active))
        for i in range(eng.batch):
            if self.slots[i] is None and self._pos[i] + 1 >= max_seq:
                eng.free_slot(i)      # recycle an idle slot's garbage rows
                self._pos[i] = 0
            elif self._pos[i] + 1 > max_seq:
                raise RuntimeError(
                    f"slot {i} position {self._pos[i]} would overflow "
                    f"max_seq_len={max_seq}")
        logits, eng.cache = eng._decode(
            eng.params, eng.cache,
            jnp.asarray(self._next_tok)[:, None])
        logits = self._filter_logits(logits, active)
        # numeric quarantine guard: one fused device-side reduction per
        # tick, fetched with the sampled tokens
        finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
        toks = self._sample(logits)
        for i in range(eng.batch):
            self._pos[i] += 1
        for i in active:
            req = self.slots[i]
            if not finite[i]:
                req.error = (
                    f"request {req.rid}: non-finite decode logits at token "
                    f"{len(req.generated) + 1}/{req.max_new} — quarantined "
                    f"with partial output")
                finished.append(
                    self._finish(i, status=RequestStatus.FAILED_NUMERIC))
                continue
            tok = int(toks[i])
            req.generated.append(tok)
            self._emit(req, tok, events)
            self._next_tok[i] = toks[i]
            if len(req.generated) >= req.max_new:
                finished.append(self._finish(i))

    def tick(self, finished: list) -> list:
        """One scheduler step: an admission pass, then (if any slot is
        active) one batched decode step.  Completed requests are appended
        to ``finished``; returns this tick's ``(request, token)`` stream
        events in generation order."""
        events: list = []
        self._tick_no += 1
        progressed = self._admit(finished, events)
        if not any(s is not None for s in self.slots):
            if self.queue and not progressed:
                # cannot happen for requests that passed _validate —
                # defensive: an empty engine must be able to admit the
                # queue head (its worst-case demand fits an empty pool)
                raise RuntimeError(
                    f"scheduler stalled: {len(self.queue)} queued "
                    f"requests but no admission possible")
            self._maybe_audit()
            return events             # everything admitted was max_new == 1
        self._decode_once(finished, events)
        self._maybe_audit()
        return events

    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests in finish order
        (requests rejected at submit() are included up front, ``status``
        / ``error`` set)."""
        finished: list[Request] = list(self.rejected)
        self.rejected = []
        while not self.idle:
            self.tick(finished)
        return finished
