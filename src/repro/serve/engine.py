"""Serving engine: chunked RSR prefill + continuous-batching decode.

``Engine`` owns the serve-parameterized tree (RSR codes + packed kernel
streams after offline ``serve_params`` conversion), a pre-allocated per-slot
KV cache, and ONE jitted step — ``tfm.prefill_step`` — that covers both
serving regimes.  C == 1 is the classic decode step (batch ≤ 8 rows, the
vector-matrix hot path the paper's 5.24× claim targets); C == prefill_chunk
is the chunked-prefill hot path: a length-S prompt costs ceil(S / chunk)
kernel launches per quantized linear instead of S, each launch flattening
B·C rows so the backend dispatcher (``repro.kernels.dispatch``) leaves the
decode tile regime for the widened small/prefill tiles and amortizes the
per-tile one-hot build across the chunk, scale/bias still fused into the
kernel epilogue.  The old decode-step ``lax.scan`` prefill survives only as
``prefill_scan`` — the exactness reference for the parity tests and the
baseline BENCH_prefill.json measures against.

All cache writes are per-slot (per-batch-row scatters at ``cache['pos']``),
so batch slots hold independent sequences at independent positions:

* ``prefill_into(slot, prompt)`` — admission: chunk-prefills ONE slot's
  rows from a fresh state while the other slots sit mid-decode, untouched.
* ``free_slot(slot)`` — eviction: re-zeros a slot's rows and position.
* ``prefill(tokens)`` — whole-batch chunked prefill (the ``generate`` path).

``BatchScheduler`` is true continuous batching over the fixed slots:
admit-on-free via per-slot prefill (no ``Engine.reset``, no head-of-line
blocking on the longest request of an admission wave), per-slot true prompt
lengths (no left padding — short prompts never attend to pad tokens), one
batched decode step per loop tick for every active slot, eviction on
completion.  A host-side position mirror guards every slot against running
past ``max_seq_len``.

``Engine.decode_throughput`` measures steady-state decode tokens/s through
the jitted step (BENCH_serve.json headline); the chunked-prefill and mixed
prefill+decode scheduler numbers land in BENCH_prefill.json
(``benchmarks/run.py --only prefill``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.models import transformer as tfm


class Engine:
    def __init__(self, cfg: ModelConfig, serve_tree: dict, scfg: ServeConfig):
        self.cfg, self.scfg = cfg, scfg
        self.params = serve_tree
        self.batch = scfg.batch_size
        self.cache = tfm.init_cache(cfg, self.batch, scfg.max_seq_len)
        # one jitted step for both regimes: (B, C) tokens -> last logits;
        # jax caches a compile per distinct C (decode C=1, the prefill
        # chunk, and at most one ragged remainder per prompt length)
        self._step = jax.jit(
            lambda p, c, t: tfm.prefill_step(p, c, t, cfg))
        self._decode = self._step                  # (B, 1): decode == C=1

        def _scan(p, c, toks):                     # toks (B, S)
            def step(c, t):
                logits, c = tfm.decode_step(p, c, t[:, None], cfg)
                return c, logits
            c, logits = jax.lax.scan(step, c, jnp.moveaxis(toks, 1, 0))
            return c, logits[-1]
        self._prefill_scan = jax.jit(_scan)
        self._write_slot = jax.jit(tfm.update_slot_cache)
        # fresh batch-1 slot state for admissions/evictions (immutable —
        # shared freely, never mutated)
        self._fresh_slot = tfm.init_cache(cfg, 1, scfg.max_seq_len)

    def reset(self):
        self.cache = tfm.init_cache(self.cfg, self.batch,
                                    self.scfg.max_seq_len)

    def free_slot(self, slot: int):
        """Zero slot's cache rows + position (eviction / pre-admission)."""
        self.cache = self._write_slot(self.cache, self._fresh_slot,
                                      jnp.int32(slot))

    def _check_capacity(self, start: int, new_tokens: int, what: str):
        """Cache writes past max_seq_len are out-of-range scatters — XLA
        DROPS them silently and the causal mask would then attend stale
        rows, so every position-advancing entry point validates first."""
        end = start + new_tokens
        if end > self.scfg.max_seq_len:
            raise ValueError(
                f"{what} would advance slot positions to {end} > "
                f"max_seq_len={self.scfg.max_seq_len} (start={start}); "
                f"reset()/free_slot() or raise max_seq_len")

    def prefill(self, tokens: jax.Array, *, chunk: Optional[int] = None,
                start: Optional[int] = None):
        """Chunked whole-batch prefill: tokens (B, S) -> last logits (B, V).

        Each chunk is one ``_step`` call — B·chunk flattened rows per
        quantized linear (the prefill tile regime) instead of the scan
        reference's S sequential single-token launches.  ``start`` is the
        caller-known max slot position (skips a per-call device sync for
        the capacity check — e.g. 0 right after reset()).
        """
        if tokens.shape[1] == 0:
            raise ValueError("prefill of an empty prompt (S == 0)")
        if start is None:
            start = int(jax.device_get(jnp.max(self.cache["pos"])))
        self._check_capacity(start, tokens.shape[1], "prefill")
        chunk = int(chunk or self.scfg.prefill_chunk)
        logits = None
        for off in range(0, tokens.shape[1], chunk):
            logits, self.cache = self._step(self.params, self.cache,
                                            tokens[:, off:off + chunk])
        return logits

    def prefill_scan(self, tokens: jax.Array):
        """Reference prefill: jitted lax.scan of single-token decode steps
        (the pre-chunking path; parity baseline for tests/BENCH_prefill)."""
        self.cache, logits = self._prefill_scan(self.params, self.cache,
                                                tokens)
        return logits

    def prefill_into(self, slot: int, prompt, *, chunk: Optional[int] = None):
        """Per-slot admission prefill: run the chunked prefill of a 1-D
        prompt through slot's rows from a fresh state; every other slot is
        untouched (they can sit mid-decode).  Returns last logits (V,)."""
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        if toks.shape[1] == 0:
            raise ValueError(f"prefill_into(slot={slot}): empty prompt")
        self._check_capacity(0, toks.shape[1], f"prefill_into(slot={slot})")
        chunk = int(chunk or self.scfg.prefill_chunk)
        sub = self._fresh_slot
        logits = None
        for start in range(0, toks.shape[1], chunk):
            logits, sub = self._step(self.params, sub,
                                     toks[:, start:start + chunk])
        self.cache = self._write_slot(self.cache, sub, jnp.int32(slot))
        return logits[0]

    def sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature)

    def generate(self, prompts: jax.Array, max_new: int, *,
                 key=None) -> np.ndarray:
        """Greedy/temperature generation. prompts (B, S) -> (B, max_new)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        start = int(jax.device_get(jnp.max(self.cache["pos"])))
        self._check_capacity(start, prompts.shape[1] + max_new, "generate")
        logits = self.prefill(prompts, start=start)
        tok = self.sample(logits, key)
        out = [np.asarray(tok)]
        # token 0 comes from the prefill logits, so only max_new - 1 decode
        # steps are needed — no trailing decode whose sample is discarded
        for _ in range(max_new - 1):
            logits, self.cache = self._decode(self.params, self.cache,
                                              tok[:, None])
            key, sub = jax.random.split(key)
            tok = self.sample(logits, sub)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)

    def decode_throughput(self, steps: int = 16, warmup: int = 2) -> dict:
        """Steady-state decode perf of the jitted step (compile excluded).

        Returns {"tokens_per_s", "us_per_step", "batch", "steps"};
        tokens/s counts all batch slots (batch · steps / wall time).
        The measurement advances a LOCAL cache (the engine's committed
        state is untouched), so slot positions are validated up front:
        silently wrapping past max_seq_len would time scatter writes that
        never land (out-of-range updates are dropped) and corrupt the
        number.
        """
        self._check_capacity(int(jax.device_get(jnp.max(self.cache["pos"]))),
                             max(1, warmup) + steps, "decode_throughput")
        tok = jnp.ones((self.batch, 1), jnp.int32)
        cache = self.cache
        for _ in range(max(1, warmup)):     # ≥1: compile must stay untimed
            logits, cache = self._decode(self.params, cache, tok)
        logits.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(steps):
            logits, cache = self._decode(self.params, cache, tok)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        return {"tokens_per_s": self.batch * steps / dt,
                "us_per_step": dt / steps * 1e6,
                "batch": self.batch, "steps": steps}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Continuous batching over the engine's fixed slots.

    Each loop tick admits queued requests into free slots (per-slot chunked
    prefill at the request's TRUE length — no left padding, no reset of the
    other slots) and then runs ONE batched decode step for every slot.
    Completed requests are evicted immediately, freeing their slot for the
    next admission — no head-of-line blocking on the longest request.
    """

    def __init__(self, engine: Engine):
        self.engine = engine
        self.slots: list[Optional[Request]] = [None] * engine.batch
        self.queue: list[Request] = []
        self._next_tok = np.zeros((engine.batch,), np.int32)
        # host mirror of per-slot cache positions: overflow guard without a
        # device sync per tick
        self._pos = [0] * engine.batch
        self._key = jax.random.PRNGKey(0)

    def submit(self, req: Request):
        self.queue.append(req)

    # -- internals ---------------------------------------------------------

    def _sample(self, logits) -> np.ndarray:        # (B, V) -> (B,)
        self._key, sub = jax.random.split(self._key)
        return np.asarray(self.engine.sample(logits, sub))

    def _finish(self, i: int) -> Request:
        req = self.slots[i]
        req.done = True
        self.slots[i] = None
        self.engine.free_slot(i)
        self._pos[i] = 0
        return req

    def _admit(self, finished: list):
        eng = self.engine
        for i in range(eng.batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            need = len(req.prompt) + req.max_new
            if need > eng.scfg.max_seq_len:
                raise ValueError(
                    f"request {req.rid}: prompt+max_new={need} exceeds "
                    f"max_seq_len={eng.scfg.max_seq_len}")
            logits = eng.prefill_into(i, req.prompt)
            tok = int(self._sample(logits[None, :])[0])
            req.generated.append(tok)
            self._pos[i] = len(req.prompt)
            self.slots[i] = req
            if len(req.generated) >= req.max_new:
                finished.append(self._finish(i))
            else:
                self._next_tok[i] = tok

    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests in finish order."""
        eng = self.engine
        max_seq = eng.scfg.max_seq_len
        finished: list[Request] = []
        while self.queue or any(s is not None for s in self.slots):
            self._admit(finished)
            active = [i for i, s in enumerate(self.slots) if s is not None]
            if not active:
                continue              # everything admitted was max_new == 1
            for i in range(eng.batch):
                if self.slots[i] is None and self._pos[i] + 1 >= max_seq:
                    eng.free_slot(i)  # recycle an idle slot's garbage rows
                    self._pos[i] = 0
                elif self._pos[i] + 1 > max_seq:
                    raise RuntimeError(
                        f"slot {i} position {self._pos[i]} would overflow "
                        f"max_seq_len={max_seq}")
            logits, eng.cache = eng._decode(
                eng.params, eng.cache,
                jnp.asarray(self._next_tok)[:, None])
            toks = self._sample(logits)
            for i in range(eng.batch):
                self._pos[i] += 1
            for i in active:
                req = self.slots[i]
                req.generated.append(int(toks[i]))
                self._next_tok[i] = toks[i]
                if len(req.generated) >= req.max_new:
                    finished.append(self._finish(i))
        return finished
