"""Durable serve plane: atomic on-disk checkpoints + write-ahead journal.

PR 7 made the plane crash-*safe* — ``PriorityScheduler.snapshot()`` is a
complete, fingerprinted host-state export and ``restore()`` resumes it
bitwise-continuously on a fresh engine — but nothing ever touched disk,
so a process crash still lost everything.  This module is the disk half:
the snapshot dict (now fully JSON-serializable) rides a versioned,
checksummed on-disk format with a write-ahead request journal between
checkpoints, so recovery after a kill is

    load newest VALID checkpoint  +  replay the journal tail

with bounded work loss (at most the events after the last good record).

On-disk format
--------------
Everything is built from one **record** frame::

    <u32 payload_len> <u32 crc32(payload)> <payload bytes>

Payloads are canonical JSON (``sort_keys=True``) — never pickle, so a
corrupted record is rejected by the CRC before any decode runs.  A
reader iterates records and STOPS at the first bad one (short header,
length past EOF, CRC mismatch, undecodable JSON): torn writes truncate,
they never crash.

* **Checkpoint** ``ckpt-<seq:08d>`` — file magic ``RPCK`` + ``<u32
  version>``, then exactly three records: a header (``kind/seq/
  version``), the snapshot state, and an end marker carrying the record
  count.  A checkpoint missing any of the three (truncated at a record
  boundary) is invalid as a whole — recovery falls back to the previous
  sequence number.  Written atomically: temp file in the same directory
  → write → fsync → rename → directory fsync.  A failed fsync ABORTS the
  publish (the temp file is deleted, the previous checkpoint stays
  newest); a torn/corrupted write that fsyncs fine publishes a bad file,
  which is exactly what the fallback ladder is for.
* **Journal** ``wal-<seq:08d>`` — file magic ``RPWL`` + version, then
  one record per event, appended as they happen.  Epoch ``seq`` holds
  the events since checkpoint ``seq`` published (``wal-0``: since
  boot).  Events: ``submit`` (full request), ``terminal`` (final status
  + exact generated tokens — a post-checkpoint completion is reported
  verbatim on recovery, never recomputed), ``preempt`` (preemption
  count).  Replay walks epochs ``loaded_seq, loaded_seq+1, ...`` in
  order and truncates at the first bad record anywhere.

Sequence numbers are monotonic (``max existing + 1``); retention keeps
the last K checkpoints plus every journal epoch needed to replay from
the oldest retained one.

Recovery ladder (:func:`recover_scheduler`)
-------------------------------------------
1. newest checkpoint, CRC/structure-valid → ``restore()`` + replay its
   journal tail;
2. corrupt → next-older checkpoint (each skip is counted in the
   report);
3. none valid → empty plane + full journal replay from ``wal-0``.

A checkpoint that is VALID but fingerprint-mismatched is a refusal
(``ValueError`` from ``restore()``), not a fallback: silently restoring
another engine's state would resume wrong KV.  After state is rebuilt,
``audit.audit_snapshot`` has already vetted the decoded dict and
``audit.audit_scheduler`` (I1-I8) runs before the scheduler is handed
back — a recovered plane never admits traffic on inconsistent books.
Recovery finishes by writing a fresh checkpoint (rotating onto a clean
journal epoch), so a torn pre-crash journal tail can never swallow
post-recovery events.

Fault seams
-----------
The store consumes the :class:`~repro.serve.faults.FaultPlan` disk
seams: every durable write (one checkpoint temp file, or one journal
append) advances the ``torn@N``/``flip@N`` write ordinal, every fsync
advances the ``fsync@N`` ordinal.  ``torn`` halves the buffer, ``flip``
XORs one bit in the middle, ``fsync`` simulates an fsync failure — the
chaos soak (``benchmarks/run.py --only durability``) kills the plane at
a random tick under all three and asserts recovery still lands zero
leaks and bitwise-continuous greedy tokens.

Operator knobs: ``ServeConfig.checkpoint_dir`` / ``checkpoint_interval``
/ ``checkpoint_interval_s`` / ``checkpoint_keep``, overridden by
``$REPRO_CHECKPOINT_DIR`` / ``$REPRO_CHECKPOINT_INTERVAL`` (see the env
table in ``repro/serve/__init__.py``).
"""
from __future__ import annotations

import base64
import json
import os
import struct
import zlib
from typing import List, Optional, Tuple

import numpy as np

from repro.serve import telemetry

__all__ = ["CheckpointStore", "pack_record", "iter_records",
           "encode_array", "decode_array", "recover_scheduler",
           "CKPT_MAGIC", "WAL_MAGIC", "FORMAT_VERSION"]

CKPT_MAGIC = b"RPCK"
WAL_MAGIC = b"RPWL"
FORMAT_VERSION = 1

_REC = struct.Struct("<II")            # payload_len, crc32
_VER = struct.Struct("<I")


# -- record framing ---------------------------------------------------------

def pack_record(payload: bytes) -> bytes:
    """Frame one payload: ``<u32 len><u32 crc32><payload>``."""
    return _REC.pack(len(payload), zlib.crc32(payload)) + payload


def iter_records(data: bytes, offset: int = 0) -> Tuple[List[bytes], bool]:
    """Parse records from ``data[offset:]``; returns ``(payloads, clean)``.

    Stops at the first bad record — short header, declared length past
    EOF (torn tail or garbage length), or CRC mismatch (bit flip) —
    with ``clean=False``.  Never raises on corrupt input.
    """
    out: List[bytes] = []
    n = len(data)
    while offset < n:
        if offset + _REC.size > n:
            return out, False           # torn mid-header
        ln, crc = _REC.unpack_from(data, offset)
        if ln > n - offset - _REC.size:
            return out, False           # torn mid-payload / garbage length
        payload = data[offset + _REC.size:offset + _REC.size + ln]
        if zlib.crc32(payload) != crc:
            return out, False           # flipped bits
        out.append(payload)
        offset += _REC.size + ln
    return out, True


def _dumps(obj) -> bytes:
    return json.dumps(obj, sort_keys=True).encode("utf-8")


def _loads(payload: bytes):
    """JSON-decode one record payload; None on any decode failure (a
    CRC-valid record with undecodable JSON only happens via version
    drift — treated exactly like corruption: stop, fall back)."""
    try:
        return json.loads(payload.decode("utf-8"))
    except (ValueError, UnicodeDecodeError):
        return None


# -- array codec (snapshot KV leaves) ---------------------------------------

def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes              # jax dependency: bfloat16 et al.
        return np.dtype(getattr(ml_dtypes, name))


def encode_array(a: np.ndarray) -> dict:
    """Lossless JSON encoding of a numpy array (dtype name + shape +
    base64 of the raw bytes) — exact for every dtype incl. bfloat16,
    unlike ``tolist()`` float round-trips."""
    a = np.ascontiguousarray(a)
    return {"__nd__": True, "dtype": a.dtype.name, "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def decode_array(d: dict) -> np.ndarray:
    dt = _np_dtype(d["dtype"])
    buf = base64.b64decode(d["data"])
    return np.frombuffer(buf, dt).reshape(d["shape"]).copy()


# -- the store --------------------------------------------------------------

class CheckpointStore:
    """Atomic checkpoints + write-ahead journal in one directory.

    ``faults`` is anything exposing the FaultPlan disk hooks
    (``take_disk_write() -> None|'torn'|'flip'`` and ``take_fsync() ->
    bool``); None disables injection.  The store is crash-tolerant by
    construction: a checkpoint is only visible after its temp file
    fsynced and renamed, and journal corruption truncates replay rather
    than failing it.
    """

    def __init__(self, dirpath: str, *, keep: int = 3, faults=None):
        self.dir = str(dirpath)
        self.keep = max(1, int(keep))
        self.faults = faults
        os.makedirs(self.dir, exist_ok=True)
        seqs = self.list_checkpoints()
        self.seq = seqs[-1] if seqs else 0   # newest published checkpoint
        self._wal_f = None                   # lazily-opened current epoch
        # dict-compatible counter view (telemetry.StatsView): exported as
        # serve_checkpoint_stats{key=} once a scheduler adopts it
        self.stats = telemetry.stats_counters(
            "serve_checkpoint_stats",
            ("checkpoints_written", "checkpoint_failures",
             "checkpoint_bytes", "journal_records", "fsync_failures",
             "torn_writes", "bit_flips", "pruned_checkpoints"),
            help="Durable checkpoint/journal store counters.")

    # -- paths / listing ----------------------------------------------------

    def _ckpt_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"ckpt-{seq:08d}")

    def _wal_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"wal-{seq:08d}")

    def _scan(self, prefix: str) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith(prefix):
                try:
                    out.append(int(name[len(prefix):]))
                except ValueError:
                    continue
        return sorted(out)

    def list_checkpoints(self) -> List[int]:
        """Published checkpoint sequence numbers, oldest first."""
        return self._scan("ckpt-")

    def list_journals(self) -> List[int]:
        return self._scan("wal-")

    # -- faulty-disk write primitives ---------------------------------------

    def _write(self, f, data: bytes) -> None:
        """One durable write op; the FaultPlan disk-write seam may tear
        it (truncate to half) or flip one bit mid-buffer."""
        mode = self.faults.take_disk_write() if self.faults is not None \
            else None
        if mode == "torn":
            data = data[:max(1, len(data) // 2)]
            self.stats["torn_writes"] += 1
        elif mode == "flip":
            b = bytearray(data)
            b[len(b) // 2] ^= 0x01
            data = bytes(b)
            self.stats["bit_flips"] += 1
        f.write(data)

    def _fsync(self, f) -> bool:
        """fsync through the fault seam; False = the sync failed (the
        data may not be on disk — the caller decides what that aborts)."""
        f.flush()
        if self.faults is not None and self.faults.take_fsync():
            self.stats["fsync_failures"] += 1
            return False
        try:
            os.fsync(f.fileno())
        except OSError:
            self.stats["fsync_failures"] += 1
            return False
        return True

    def _fsync_dir(self) -> None:
        try:
            fd = os.open(self.dir, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        except OSError:                 # platform without dir fsync: best
            pass                        # effort — rename is still atomic

    # -- checkpoints --------------------------------------------------------

    def write_checkpoint(self, snap: dict) -> bool:
        """Atomically publish ``snap`` as checkpoint ``self.seq + 1``.

        Returns True on publish (sequence advanced, journal rotated onto
        the new epoch, retention pruned).  A failed fsync returns False
        and leaves the previous checkpoint newest — an un-synced rename
        could surface a checkpoint that evaporates on power loss, so the
        publish is abandoned instead.
        """
        seq = self.seq + 1
        records = [
            _dumps({"kind": "header", "seq": seq,
                    "version": FORMAT_VERSION}),
            _dumps({"kind": "state", "snapshot": snap}),
        ]
        records.append(_dumps({"kind": "end", "records": len(records) + 1}))
        blob = CKPT_MAGIC + _VER.pack(FORMAT_VERSION) + b"".join(
            pack_record(p) for p in records)
        tmp = os.path.join(self.dir, f".tmp-ckpt-{seq:08d}")
        try:
            with open(tmp, "wb") as f:
                self._write(f, blob)
                ok = self._fsync(f)
            if not ok:
                os.unlink(tmp)
                self.stats["checkpoint_failures"] += 1
                return False
            os.replace(tmp, self._ckpt_path(seq))
        except OSError:
            self.stats["checkpoint_failures"] += 1
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        self._fsync_dir()
        self.seq = seq
        self.stats["checkpoints_written"] += 1
        self.stats["checkpoint_bytes"] = len(blob)
        self._rotate_journal()
        self._retire()
        return True

    def read_checkpoint(self, seq: int) -> Optional[dict]:
        """Decode checkpoint ``seq``; None on ANY corruption (missing
        file, bad magic/version, torn/flipped records, missing header/
        state/end structure) — never raises on bad bytes."""
        try:
            with open(self._ckpt_path(seq), "rb") as f:
                data = f.read()
        except OSError:
            return None
        if len(data) < len(CKPT_MAGIC) + _VER.size \
                or data[:len(CKPT_MAGIC)] != CKPT_MAGIC:
            return None
        (ver,) = _VER.unpack_from(data, len(CKPT_MAGIC))
        if ver != FORMAT_VERSION:
            return None
        payloads, clean = iter_records(data, len(CKPT_MAGIC) + _VER.size)
        if not clean or len(payloads) < 3:
            return None
        recs = [_loads(p) for p in payloads]
        if any(r is None or not isinstance(r, dict) for r in recs):
            return None
        head, foot = recs[0], recs[-1]
        if head.get("kind") != "header" or head.get("seq") != seq \
                or head.get("version") != FORMAT_VERSION:
            return None
        if foot.get("kind") != "end" or foot.get("records") != len(recs):
            return None
        state = next((r for r in recs[1:-1] if r.get("kind") == "state"), None)
        if state is None or "snapshot" not in state:
            return None
        return state["snapshot"]

    def load_best(self) -> Tuple[Optional[int], Optional[dict], int]:
        """Newest valid checkpoint: ``(seq, snapshot, skipped)`` where
        ``skipped`` counts corrupt newer checkpoints that were passed
        over; ``(None, None, skipped)`` when no checkpoint decodes."""
        skipped = 0
        for seq in reversed(self.list_checkpoints()):
            snap = self.read_checkpoint(seq)
            if snap is not None:
                return seq, snap, skipped
            skipped += 1
        return None, None, skipped

    # -- journal ------------------------------------------------------------

    def _rotate_journal(self) -> None:
        if self._wal_f is not None:
            self._wal_f.close()
            self._wal_f = None

    def append(self, event: dict) -> None:
        """Append one event record to the current journal epoch (opened
        lazily; a brand-new epoch file gets its magic+version header in
        the same durable write as the first record).  An fsync failure
        here is tolerated and counted — the event may be lost on a
        crash, which recovery treats as any other truncated tail."""
        blob = pack_record(_dumps(event))
        if self._wal_f is None:
            path = self._wal_path(self.seq)
            fresh = not os.path.exists(path)
            self._wal_f = open(path, "ab")
            if fresh:
                blob = WAL_MAGIC + _VER.pack(FORMAT_VERSION) + blob
        self._write(self._wal_f, blob)
        self._fsync(self._wal_f)
        self.stats["journal_records"] += 1

    def read_journal(self, from_seq: int) -> Tuple[List[dict], bool]:
        """Replay events from journal epochs ``>= from_seq`` in order;
        ``(events, truncated)``.  Truncates at the first bad record or
        bad epoch file and IGNORES every later epoch (events after a
        hole cannot be ordered against the lost ones)."""
        events: List[dict] = []
        if self._wal_f is not None:     # same-process read: land buffers
            self._wal_f.flush()
        for seq in self.list_journals():
            if seq < from_seq:
                continue
            try:
                with open(self._wal_path(seq), "rb") as f:
                    data = f.read()
            except OSError:
                return events, True
            hdr = len(WAL_MAGIC) + _VER.size
            if len(data) < hdr or data[:len(WAL_MAGIC)] != WAL_MAGIC:
                return events, True
            (ver,) = _VER.unpack_from(data, len(WAL_MAGIC))
            if ver != FORMAT_VERSION:
                return events, True
            payloads, clean = iter_records(data, hdr)
            for p in payloads:
                ev = _loads(p)
                if ev is None or not isinstance(ev, dict):
                    return events, True
                events.append(ev)
            if not clean:
                return events, True
        return events, False

    # -- retention ----------------------------------------------------------

    def _retire(self) -> None:
        """Keep the last K checkpoints and every journal epoch >= the
        oldest retained VALID checkpoint's.  Validity (not mere
        existence) is the pruning bar: a published checkpoint that a
        disk fault corrupted would otherwise license deleting the only
        surviving copy of its requests — the journal epochs its content
        was supposed to absorb.  No valid base -> no journal pruning
        (recovery may need the full wal-0 replay)."""
        seqs = self.list_checkpoints()
        for seq in seqs[:-self.keep]:
            try:
                os.unlink(self._ckpt_path(seq))
                self.stats["pruned_checkpoints"] += 1
            except OSError:
                pass
        base = next((seq for seq in self.list_checkpoints()
                     if self.read_checkpoint(seq) is not None), None)
        if base is None:
            return
        for seq in self.list_journals():
            if seq < base:
                try:
                    os.unlink(self._wal_path(seq))
                except OSError:
                    pass

    def close(self) -> None:
        self._rotate_journal()


# -- recovery ---------------------------------------------------------------

def recover_scheduler(engine, *, clock=None, dirpath: Optional[str] = None,
                      fault_plan=None):
    """Boot a :class:`~repro.serve.frontend.PriorityScheduler` from disk:
    newest valid checkpoint + journal-tail replay, audited before it is
    handed back.  Returns ``(scheduler, report)``.

    The checkpoint directory resolves like the scheduler's own policy
    (``$REPRO_CHECKPOINT_DIR`` > ``ServeConfig.checkpoint_dir``) unless
    ``dirpath`` overrides it; the recovered scheduler keeps journaling
    and checkpointing to the same directory.  Raises ``ValueError`` when
    no directory is configured or when the newest VALID checkpoint's
    fingerprint does not match ``engine`` (restoring another engine's KV
    would be silent corruption — corrupt checkpoints fall back, wrong-
    engine ones refuse).

    ``report`` keys: ``checkpoint_seq`` (None = from-scratch),
    ``checkpoints_skipped`` (corrupt newer ones passed over),
    ``journal_events`` / ``journal_truncated``, ``requeued`` (requests
    back in the queue), ``completed`` (Request objects whose terminal
    journal events post-date the checkpoint — their exact tokens, never
    recomputed), ``resumed_inflight`` (requeued with partial output).
    """
    from repro.serve import audit                    # lazy: no import cycle
    from repro.serve.engine import Request, RequestStatus
    from repro.serve.frontend import PriorityScheduler

    sched = PriorityScheduler(engine, clock=clock, fault_plan=fault_plan)
    if dirpath is not None and sched._ckpt_store is None:
        sched._ckpt_store = CheckpointStore(
            dirpath, keep=int(getattr(engine.scfg, "checkpoint_keep", 3)),
            faults=sched.fault_plan)
    store = sched._ckpt_store
    if store is None:
        raise ValueError(
            "recover_scheduler: no checkpoint directory configured — set "
            "ServeConfig.checkpoint_dir, $REPRO_CHECKPOINT_DIR, or pass "
            "dirpath=")
    seq, snap, skipped = store.load_best()
    if snap is not None:
        audit.audit_snapshot(snap)
        sched.restore(snap)             # ValueError on fingerprint mismatch
    by_rid = {r.rid: r for r in sched.queue}
    events, truncated = store.read_journal(seq if seq is not None else 0)
    completed: dict = {}
    for ev in events:
        kind = ev.get("ev")
        if kind == "submit":
            d = ev.get("req") or {}
            rid = d.get("rid")
            if rid is None or rid in by_rid or rid in completed:
                continue
            req = Request.from_json(d)
            req.done = False
            req.status = (RequestStatus.PREEMPTED if req.generated
                          else RequestStatus.QUEUED)
            by_rid[rid] = req
            sched.queue.append(req)
        elif kind == "preempt":
            req = by_rid.get(ev.get("rid"))
            if req is not None:
                req.preemptions = max(req.preemptions,
                                      int(ev.get("n", 0)))
        elif kind == "terminal":
            d = ev.get("req") or {}
            rid = d.get("rid")
            if rid is None:
                continue
            req = by_rid.pop(rid, None)
            if req is not None:
                sched.queue.remove(req)
            completed[rid] = Request.from_json(d)
    audit.audit_scheduler(sched)        # I1-I8 before any traffic
    report = {
        "checkpoint_seq": seq,
        "checkpoints_skipped": skipped,
        "journal_events": len(events),
        "journal_truncated": truncated,
        "requeued": len(sched.queue),
        "resumed_inflight": sum(1 for r in sched.queue if r.generated),
        "completed": list(completed.values()),
    }
    # draw a clean recovery line: a fresh checkpoint of the rebuilt state
    # rotates onto a new journal epoch, so a torn pre-crash tail cannot
    # sit in front of post-recovery events (fsync-fault here is tolerated
    # — the plane serves on, the next periodic checkpoint retries)
    sched.checkpoint()
    return sched, report
