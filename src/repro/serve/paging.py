"""Block-paged KV cache: layout, host-side allocator, and prefix hashing.

The dense serve cache gives every batch slot a private ``max_seq_len`` row per
attention layer, so admission is gated on ``prompt + max_new <= max_seq_len``
and identical prompt prefixes are recomputed and stored once per request.
This module supplies the vLLM-style alternative: one global pool of
fixed-size KV *blocks* per attention layer, per-slot *block tables* mapping
logical sequence blocks to physical pool blocks, and ref-counted sharing of
common prompt-prefix blocks.

Division of labour:

* :class:`PagedLayout` — the static geometry (block size, pool size, logical
  blocks per slot split into a *full-attention* region and a *ring* region
  for sliding-window layers).  Hashable, so the jitted step can close over
  it.  Built by :func:`paged_layout` from ``(ModelConfig, ServeConfig)``.
* :class:`BlockPool` — the host-side allocator: free list, per-block
  refcounts, and the content-hash -> block map that backs prefix sharing.
  Pure Python/NumPy; device arrays never flow through it.
* :func:`block_hashes` — chained content hashes of full prompt blocks.  The
  chain makes a block's identity include its prefix context, so equal hashes
  imply equal KV content (same tokens at the same absolute positions).

Device-side storage (see ``repro.models.transformer.init_cache`` /
``repro.models.attention``): each attention layer's cache becomes a pool
array with a leading physical-block axis (``num_blocks + 1`` — the extra
*trash* block absorbs writes from idle batch rows so they can never corrupt
a live request's blocks), and the cache tree gains one shared
``table (batch, mb_full + mb_ring) int32`` of physical block ids.  Recurrent
(SSM) and cross-attention states are position-free and stay per-slot.

Sharing rules:

* Only FULL prompt blocks are ever registered for sharing.  Partial tail
  blocks and every decode-time block are private.
* A registered block whose LAST reference is freed is not returned to the
  free list immediately: it moves to the WARM list — still content-
  addressable by its hash (a later admission with the same prefix revives
  it at zero prefill cost), but reclaimable at any moment.  ``alloc``
  drains the free list first and then reclaims warm blocks oldest-freed
  first (LRU), evicting their hash registration; a warm hit therefore no
  longer requires a resident holder, which lifts hit rates across quiet
  periods (ROADMAP follow-on (d)).  ``free_count`` counts free + warm —
  the capacity the scheduler can actually claim.
* Ring-region blocks are always private: ring content depends on wrap
  history, not just token identity.
* Prefix reuse is enabled only for model families whose entire cached state
  is reconstructable from shared blocks — pure full-attention stacks
  (:func:`prefix_sharing_supported`).  Hybrid/SSM/windowed families still
  get paging (pool-capacity admission), just no cross-request reuse,
  because their recurrent/ring state at the shared boundary is not
  addressable by content hash.  (Follow-on: state snapshots per ROADMAP.)
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from collections import OrderedDict
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.serve import telemetry

__all__ = ["PagedLayout", "BlockPool", "BlockPoolExhausted", "paged_layout",
           "block_hashes", "prefix_sharing_supported", "env_fault_injector"]


class BlockPoolExhausted(RuntimeError):
    """Raised by BlockPool.alloc when the free list cannot satisfy a
    request.  The scheduler avoids it by checking blocks_needed() against
    free_count before admission (defer, don't crash); the priority request
    plane (repro.serve.frontend) additionally CATCHES it mid-decode and
    preempts a victim instead."""


def env_fault_injector() -> Optional[Callable[[int, int], bool]]:
    """Build a deterministic fault injector from ``$REPRO_FAULT_ALLOC``.

    The variable is a comma-separated list of 1-based ``alloc()`` call
    ordinals (counted per BlockPool instance, successful or not): each
    listed call raises :class:`BlockPoolExhausted` before taking any block,
    then the counter moves on — so every listed fault fires exactly once
    and a retry of the same logical allocation succeeds.  This makes the
    exhaustion / preemption / rollback paths testable without hand-tuning
    pool sizes.  Empty or unset disables injection (returns None).
    """
    spec = os.environ.get("REPRO_FAULT_ALLOC", "").strip()
    if not spec:
        return None
    try:
        ordinals = frozenset(int(tok) for tok in spec.split(",") if tok)
    except ValueError as e:
        raise ValueError(
            f"REPRO_FAULT_ALLOC={spec!r}: expected comma-separated integer "
            f"alloc ordinals (e.g. '3' or '2,5')") from e

    def injector(call: int, n: int) -> bool:
        return call in ordinals
    return injector


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static paged-cache geometry (hashable: jitted steps close over it).

    ``mb_full`` logical blocks per slot serve the full-attention/MLA layers
    (absolute position p lives in logical block p // block_size); ``mb_ring``
    logical blocks serve sliding-window ring buffers (ring slot r lives in
    logical block mb_full + r // block_size).  The physical pool has
    ``num_blocks`` allocatable blocks plus one trailing *trash* block
    (id == num_blocks) that idle batch rows write into.
    """
    block_size: int
    num_blocks: int
    mb_full: int
    mb_ring: int
    ring_slots: int                   # dense ring length (min(max_seq, win))
    max_seq: int

    @property
    def mb_total(self) -> int:
        return self.mb_full + self.mb_ring

    @property
    def trash_block(self) -> int:
        return self.num_blocks

    def blocks_for(self, tokens: int) -> int:
        """Full-region blocks covering positions [0, tokens)."""
        if self.mb_full == 0:
            return 0
        return min(-(-tokens // self.block_size), self.mb_full)

    def blocks_for_admission(self, prompt_len: int, reserve: int) -> int:
        """Full-region blocks an admission must hold.  With an explicit
        decode reservation the caller has stated its horizon, so the count
        is exact (``blocks_for(prompt + reserve)`` — what the scheduler's
        capacity accounting relies on).  With ``reserve == 0`` (direct
        engine use, horizon unknown) one block of decode headroom past the
        prompt is added (when the table allows) so a prefill-then-decode
        never silently writes the trash block; decoding past that headroom
        without re-reserving is a contract violation."""
        if self.mb_full == 0:
            return 0
        if reserve > 0:
            return self.blocks_for(prompt_len + reserve)
        return min(self.blocks_for(prompt_len) + 1, self.mb_full)


def _attn_kinds(cfg: ModelConfig) -> list[str]:
    from repro.models.transformer import layer_kinds
    return layer_kinds(cfg)


def prefix_sharing_supported(cfg: ModelConfig) -> bool:
    """True iff every cached layer's state is fully reconstructable from
    shared prefix blocks: pure full-attention stacks (GQA window=0 or MLA).
    Recurrent/windowed/cross-attention layers carry per-slot state that a
    content-hash cannot address, so sharing is disabled for them."""
    kinds = set(_attn_kinds(cfg))
    return kinds == {"attn"} and cfg.window == 0 and not cfg.is_encoder


def paged_layout(cfg: ModelConfig, scfg: ServeConfig) -> Optional[PagedLayout]:
    """Build the layout for (cfg, scfg); None when paging is disabled."""
    bs = scfg.kv_block_size
    if bs <= 0:
        return None
    kinds = _attn_kinds(cfg)
    has_full = any(k == "attn" for k in kinds) and (
        cfg.attention == "mla" or cfg.window == 0)
    has_ring = any(k == "attn" for k in kinds) and (
        cfg.attention != "mla" and cfg.window > 0)
    mb_full = -(-scfg.max_seq_len // bs) if has_full else 0
    ring_slots = min(scfg.max_seq_len, cfg.window) if has_ring else 0
    if ring_slots and ring_slots % bs:
        raise ValueError(
            f"kv_block_size={bs} must divide the sliding-window ring length "
            f"{ring_slots} (= min(max_seq_len, window)); pick a divisor")
    mb_ring = ring_slots // bs
    num = scfg.kv_num_blocks or scfg.batch_size * (mb_full + mb_ring)
    return PagedLayout(block_size=bs, num_blocks=num, mb_full=mb_full,
                       mb_ring=mb_ring, ring_slots=ring_slots,
                       max_seq=scfg.max_seq_len)


def block_hashes(tokens: np.ndarray, block_size: int) -> List[bytes]:
    """Chained hashes of the FULL blocks of a 1-D token array.  Block j's
    hash covers tokens [0, (j+1)*block_size) through the chain, so a hash
    hit implies the whole prefix matches, not just that one block."""
    toks = np.asarray(tokens, np.int64)
    out: List[bytes] = []
    h = b""
    for j in range(len(toks) // block_size):
        h = hashlib.sha1(
            h + toks[j * block_size:(j + 1) * block_size].tobytes()).digest()
        out.append(h)
    return out


class BlockPool:
    """Host-side block allocator with refcounts, prefix-hash sharing, and a
    warm list of freed-but-still-registered blocks (LRU-reclaimed).

    All methods are O(blocks touched); no device arrays pass through here.
    ``stats`` accumulates admission-time prefix-cache counters for the
    benchmark harness (hit-rate = hit_tokens / lookup_tokens;
    ``warm_hit_blocks`` counts revivals of evicted-but-unreclaimed blocks,
    ``warm_reclaims`` counts warm blocks cannibalized by ``alloc``).
    """

    def __init__(self, num_blocks: int, block_size: int, *,
                 sharing: bool = True,
                 fault_injector: Optional[Callable[[int, int], bool]] = None):
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.sharing = bool(sharing)
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self._ref = np.zeros(self.num_blocks, np.int64)
        self._hash_to_bid: dict[bytes, int] = {}
        self._bid_to_hash: dict[int, bytes] = {}
        # freed blocks whose hash registration is kept until reclaimed;
        # insertion order == freeing order, so popitem(last=False) is LRU
        self._warm: "OrderedDict[int, bytes]" = OrderedDict()
        # fault-injection seam: ``injector(call_ordinal, n_blocks) -> bool``
        # consulted at the top of every alloc() (1-based ordinal, counted
        # whether or not the call would succeed); True raises
        # BlockPoolExhausted before any block is taken.  None falls back to
        # $REPRO_FAULT_ALLOC parsing (env_fault_injector).
        self.fault_injector = (fault_injector if fault_injector is not None
                               else env_fault_injector())
        self._alloc_calls = 0
        # dict-compatible counter view (telemetry.StatsView): same call
        # sites as the old plain dict, exported as serve_pool_stats{key=}
        # once a scheduler adopts it into its registry
        self.stats = telemetry.stats_counters(
            "serve_pool_stats",
            ("admissions", "lookup_tokens", "hit_tokens", "cow_copies",
             "warm_hit_blocks", "warm_reclaims", "faults_injected"),
            help="Block-pool allocator/prefix-sharing counters.")

    # -- bookkeeping -------------------------------------------------------

    @property
    def free_count(self) -> int:
        """Blocks an alloc() can claim: truly free + warm (reclaimable)."""
        return len(self._free) + len(self._warm)

    @property
    def warm_count(self) -> int:
        return len(self._warm)

    def is_warm(self, bid: int) -> bool:
        return bid in self._warm

    @property
    def live_refs(self) -> int:
        return int(self._ref.sum())

    def alloc(self, n: int = 1) -> List[int]:
        """Take n fresh blocks (refcount 1 each); raises BlockPoolExhausted
        when fewer than n are claimable (no partial allocation).  The free
        list drains first; then warm blocks are reclaimed oldest-freed
        first, evicting their hash registration."""
        self._alloc_calls += 1
        if self.fault_injector and self.fault_injector(self._alloc_calls, n):
            self.stats["faults_injected"] += 1
            raise BlockPoolExhausted(
                f"fault-injected: alloc call #{self._alloc_calls} (n={n}) "
                f"failed by injector")
        if n > self.free_count:
            raise BlockPoolExhausted(
                f"need {n} blocks, {self.free_count} free "
                f"(pool={self.num_blocks})")
        bids = []
        for _ in range(n):
            if self._free:
                bid = self._free.pop()
            else:
                bid, _h = self._warm.popitem(last=False)    # LRU reclaim
                self._evict_registration(bid)
                self.stats["warm_reclaims"] += 1
            self._ref[bid] = 1
            bids.append(bid)
        return bids

    def _evict_registration(self, bid: int) -> None:
        h = self._bid_to_hash.pop(bid, None)
        if h is not None and self._hash_to_bid.get(h) == bid:
            del self._hash_to_bid[h]

    def free(self, bid: int) -> None:
        """Drop one reference.  At zero a hash-registered block moves to
        the warm list (still matchable, reclaimable); an unregistered one
        returns straight to the free list."""
        if self._ref[bid] <= 0:
            raise ValueError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            h = self._bid_to_hash.get(bid)
            if (self.sharing and h is not None
                    and self._hash_to_bid.get(h) == bid):
                self._warm[bid] = h        # keep registration until reclaim
            else:
                self._evict_registration(bid)
                self._free.append(bid)

    # -- prefix sharing ----------------------------------------------------

    def match_prefix(self, hashes: Sequence[bytes]) -> List[int]:
        """Longest chain of matchable shared blocks for `hashes` — resident
        holders AND warm (evicted-but-unreclaimed) blocks (no incref — a
        capacity estimate for admission control)."""
        out: List[int] = []
        if not self.sharing:
            return out
        for h in hashes:
            bid = self._hash_to_bid.get(h)
            if bid is None:
                break
            out.append(bid)
        return out

    def take_prefix(self, hashes: Sequence[bytes]) -> List[int]:
        """match_prefix + claim each hit (incref; a warm hit is revived off
        the warm list first — its contents are still in the pool, so the
        admission pays zero prefill for it); updates the hit-rate stats
        (lookup_tokens counts the full-block portion of the prompt)."""
        hits = self.match_prefix(hashes)
        for bid in hits:
            if bid in self._warm:
                del self._warm[bid]        # revive: warm -> resident
                self._ref[bid] = 1
                self.stats["warm_hit_blocks"] += 1
            else:
                self._ref[bid] += 1
        self.stats["admissions"] += 1
        self.stats["lookup_tokens"] += len(hashes) * self.block_size
        self.stats["hit_tokens"] += len(hits) * self.block_size
        return hits

    def seed_warm(self, bid: int, h: bytes) -> None:
        """Crash-restore seam: claim ``bid`` off the free list and seat it
        directly on the WARM list under hash ``h`` — registered, refcount
        0, matchable, reclaimable — as if it had been written, shared and
        freed in a previous life.  The caller must have uploaded the
        block's KV contents to the device pool first (Engine.
        import_blocks); seeding order defines warm-LRU age (seed
        oldest-first).  Raises when ``bid`` is not free or ``h`` is
        already registered."""
        if not self.sharing:
            raise ValueError("seed_warm requires a sharing-enabled pool")
        if h in self._hash_to_bid:
            raise ValueError(
                f"seed_warm: hash {h.hex()[:12]} already registered to "
                f"block {self._hash_to_bid[h]}")
        try:
            self._free.remove(bid)
        except ValueError:
            raise ValueError(f"seed_warm: block {bid} is not free "
                             f"(ref={int(self._ref[bid])})") from None
        self._hash_to_bid[h] = bid
        self._bid_to_hash[bid] = h
        self._warm[bid] = h

    def register(self, bid: int, h: bytes) -> None:
        """Publish a fully-written prompt block for future sharing.  First
        writer wins: an existing registration for the same hash is kept
        (both blocks hold identical content; re-pointing would orphan
        references)."""
        if not self.sharing or h in self._hash_to_bid:
            return
        self._hash_to_bid[h] = bid
        self._bid_to_hash[bid] = h

    def ensure_exclusive(self, bid: int) -> tuple[int, bool]:
        """Copy-on-write: if `bid` is shared (refcount > 1), allocate a
        private replacement and move one reference to it; the CALLER must
        copy the device contents bid -> new before writing.  Returns
        (block to use, whether a copy is required)."""
        if self._ref[bid] <= 1:
            return bid, False
        (new,) = self.alloc(1)
        self._ref[bid] -= 1
        self.stats["cow_copies"] += 1
        return new, True
