"""Architecture registry: one module per assigned arch + the paper's own models."""
