"""HuBERT-XLarge encoder backbone [arXiv:2106.07447]. Audio frontend is a stub:
input_specs provides precomputed frame embeddings (B, S, d)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    attention="gqa", causal=False, is_encoder=True,
    act="gelu", glu=False, norm="layernorm",
    frontend="audio_stub",
)
