"""Llama3-8B-1.58bit — the paper's §5.3/§5.4 evaluation model (Ma et al. 2024
recipe). Matrix sizes 2^12..~2^13.5, matching the paper's reported range."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b-1.58bit", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    attention="gqa", rope_theta=5e5,
)
