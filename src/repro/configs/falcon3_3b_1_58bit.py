"""Falcon3-3B-1.58bit — paper §5.3/§5.4 evaluation model."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon3-3b-1.58bit", family="dense",
    num_layers=22, d_model=3072, num_heads=12, num_kv_heads=4,
    d_ff=9216, vocab_size=131072,
    attention="gqa",
)
