"""Falcon3-10B-1.58bit — paper §5.3/§5.4 evaluation model."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon3-10b-1.58bit", family="dense",
    num_layers=40, d_model=3072, num_heads=12, num_kv_heads=4,
    d_ff=23040, vocab_size=131072,
    attention="gqa",
)
