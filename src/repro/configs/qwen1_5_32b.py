"""Qwen1.5-32B [hf:Qwen]: dense MHA (kv=40), QKV bias, SwiGLU."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064,
    attention="gqa", qkv_bias=True,
)
