"""IBM Granite 3.0 MoE 3B-a800m [hf:ibm-granite]. Spec column: 40 routed
experts, top-8, expert d_ff=512 (see DESIGN.md on the 32-vs-40 discrepancy)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    attention="gqa",
    num_experts=40, num_experts_per_tok=8, moe_d_ff=512,
    tie_embeddings=True,
)
