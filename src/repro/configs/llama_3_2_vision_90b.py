"""Llama-3.2-Vision-90B text backbone [hf:meta-llama]: 100 layers, 1 gated
cross-attn per 5, GQA kv=8. Vision frontend is a stub (precomputed patch
embeddings (B, 6400, d) via input_specs)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    attention="gqa", cross_attn_every=5,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    num_image_tokens=6400, frontend="vision_stub",
)
