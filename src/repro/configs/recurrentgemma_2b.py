"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: RG-LRU + local attention,
pattern (rec, rec, attn), MQA kv=1, window 2048, GeGLU."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    attention="gqa", window=2048, block_pattern=("rec", "rec", "attn"),
    d_rnn=2560, act="gelu", glu=True,
    tie_embeddings=True,
)
