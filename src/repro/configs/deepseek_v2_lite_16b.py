"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: MLA (kv_lora=512, decoupled RoPE
64), MoE 64 routed + 2 shared, top-6, expert d_ff=1408, first layer dense."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    attention="mla", kv_lora_rank=512, q_lora_rank=0,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    num_experts=64, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1408, first_dense_layers=1,
)
