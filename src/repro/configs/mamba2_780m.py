"""Mamba-2 780M, SSD (state-space duality) [arXiv:2405.21060]. Attention-free."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    attention="none", block_pattern=("mamba",),
    ssm_state=128, expand=2, conv_width=4, ssm_head_dim=64,
    tie_embeddings=True,
)
