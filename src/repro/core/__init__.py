"""RSR/RSR++ core: the paper's contribution as a composable JAX module."""
from repro.core.api import default_k, preprocess, rsr_matmul, RSR_TPU_K
from repro.core.binlib import bin_matrix, tern_matrix, binary_row_codes, \
    ternary_row_codes
from repro.core.preprocess import (BinaryRSRIndex, TernaryDirectIndex,
                                   TernaryRSRIndex,
                                   code_traffic_bits_per_weight, index_nbytes,
                                   optimal_k_rsr, optimal_k_rsrpp,
                                   pack_code_words, preprocess_binary,
                                   preprocess_ternary,
                                   preprocess_ternary_direct,
                                   unpack_code_words)
from repro.core.rsr import (rsr_matmul_binary, rsr_matmul_ternary,
                            rsr_matmul_ternary_direct, segmented_sum,
                            segmented_sum_onehot, segmented_sum_scatter)
from repro.core.rsrpp import fold_bin_product
from repro.core.ternary import (absmean_quantize, decompose_ternary,
                                pack2bit, random_binary, random_ternary,
                                recompose_ternary, ste_ternary, unpack2bit)
