"""RSR++ Step 2: the O(2^k) pairwise-fold product u · Bin_[k] (Paper §4.3, Alg. 3).

Correctness derivation (matches the paper's (i)/(ii) loop): with 0-indexed
patterns, column k-1 of Bin_[k] (the LSB column) has a 1 exactly at odd pattern
values, so

    r[k-1] = Σ_{p odd} u[p]                       ... step (i)

and summing adjacent pairs x[q] = u[2q] + u[2q+1] marginalizes the LSB out,
leaving the identical (k-1)-bit subproblem on a half-length vector
                                                   ... step (ii)

Iterating emits outputs LSB→MSB ("from the k-th element to the first") with
total work 2^k + 2^{k-1} + ... = O(2^k) adds, log-depth — ideal for the TPU
VPU (each fold is a reshape + lane-reduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fold_bin_product"]


def fold_bin_product(u: jax.Array) -> jax.Array:
    """u (..., 2^k)  ->  u · Bin_[k]  (..., k), via Algorithm 3.

    k is derived from the trailing dimension (must be a power of two).
    """
    p = u.shape[-1]
    k = p.bit_length() - 1
    if 2 ** k != p:
        raise ValueError(f"trailing dim must be 2^k, got {p}")
    outs = []
    x = u
    for _ in range(k):
        pairs = x.reshape(*x.shape[:-1], -1, 2)
        outs.append(pairs[..., 1].sum(axis=-1))   # (i): odd-pattern sum
        x = pairs.sum(axis=-1)                    # (ii): marginalize LSB
    # outs[0] is the LSB column (r[k-1]); stack back in MSB..LSB order.
    return jnp.stack(outs[::-1], axis=-1)
