"""Inference-time RSR multiplication (Paper §4, Algorithm 2).

Three mathematically identical evaluation strategies are provided, matching
the three execution regimes we care about:

  * ``segments``  — paper-faithful Eq. 5: segmented sums over the σ-permuted
                    vector at the L boundaries, evaluated with an exclusive
                    prefix sum (sum of a contiguous range = difference of two
                    prefix values).  This is the direct transcription of the
                    paper's CPU algorithm into vector form.
  * ``scatter``   — in-place bucket accumulation keyed by the per-row code
                    (the composition σ∘L collapses to "add v[r] to bucket
                    code[r]"); used as a second oracle and the fastest pure-JAX
                    CPU path.
  * ``onehot``    — the TPU-native formulation (DESIGN.md §2): per block,
                    ``u = v · OneHot(codes)`` — an MXU matmul whose HBM traffic
                    is the code array only.  The Pallas kernel in
                    ``repro.kernels.rsr_onehot`` implements exactly this; the
                    function here is its pure-jnp oracle.

Step 2 (``u · Bin_[k]``) runs either as the plain small matmul (RSR) or the
O(2^k) pairwise fold (RSR++, see rsrpp.py).

All entry points accept batched activations ``v`` of shape (..., n) and return
(..., m).  Everything is jit-able and differentiable w.r.t. ``v`` (the index is
static data — the paper's core premise).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import binlib
from repro.core.preprocess import (BinaryRSRIndex, TernaryDirectIndex,
                                   TernaryRSRIndex)
from repro.core.rsrpp import fold_bin_product

__all__ = [
    "segmented_sum", "segmented_sum_scatter", "segmented_sum_onehot",
    "rsr_matmul_binary", "rsr_matmul_ternary", "rsr_matmul_ternary_direct",
]


# ---------------------------------------------------------------------------
# Step 1: segmented sums  u[i, j] = Σ_{r : code_i(r) = j} v[r]
# ---------------------------------------------------------------------------

def segmented_sum(v: jax.Array, perm: jax.Array, seg: jax.Array) -> jax.Array:
    """Paper-faithful Eq. 5 via prefix sums.

    v    : (..., n) activations
    perm : (nb, n)   σ per block
    seg  : (nb, P+1) full segmentation with sentinel
    ->     (..., nb, P) segmented sums
    """
    vp = v[..., perm]                                     # (..., nb, n) permuted
    zeros = jnp.zeros((*vp.shape[:-1], 1), vp.dtype)
    ps = jnp.concatenate([zeros, jnp.cumsum(vp, axis=-1)], axis=-1)
    seg_b = jnp.broadcast_to(seg, (*vp.shape[:-2], *seg.shape))
    hi = jnp.take_along_axis(ps, seg_b[..., 1:], axis=-1)
    lo = jnp.take_along_axis(ps, seg_b[..., :-1], axis=-1)
    return hi - lo


def segmented_sum_scatter(v: jax.Array, codes: jax.Array,
                          num_patterns: int) -> jax.Array:
    """Bucket scatter-add form: u[..., i, code[i, r]] += v[..., r]."""
    nb, n = codes.shape

    def one(vv: jax.Array) -> jax.Array:                  # vv: (n,)
        u = jnp.zeros((nb, num_patterns), vv.dtype)
        block_ids = jnp.broadcast_to(jnp.arange(nb)[:, None], codes.shape)
        return u.at[block_ids, codes.astype(jnp.int32)].add(
            jnp.broadcast_to(vv, (nb, n)))

    flat = v.reshape(-1, v.shape[-1])
    out = jax.vmap(one)(flat)
    return out.reshape(*v.shape[:-1], nb, num_patterns)


def segmented_sum_onehot(v: jax.Array, codes: jax.Array,
                         num_patterns: int) -> jax.Array:
    """One-hot MXU form: u = v · OneHot(codes) per block (oracle for Pallas)."""
    onehot = (codes[..., None] ==
              jnp.arange(num_patterns, dtype=jnp.int32)).astype(v.dtype)
    return jnp.einsum("...n,bnp->...bp", v, onehot)


_SS_IMPLS = ("segments", "scatter", "onehot")


def _seg_sums(v, idx, num_patterns, impl):
    if impl == "segments":
        return segmented_sum(v, idx.perm, idx.seg)
    if impl == "scatter":
        return segmented_sum_scatter(v, idx.codes, num_patterns)
    if impl == "onehot":
        return segmented_sum_onehot(v, idx.codes, num_patterns)
    raise ValueError(f"impl must be one of {_SS_IMPLS}, got {impl!r}")


# ---------------------------------------------------------------------------
# Step 2 + assembly
# ---------------------------------------------------------------------------

def _block_product(u: jax.Array, pattern_matrix: jax.Array,
                   plus_plus: bool) -> jax.Array:
    """(..., nb, P) × (P, k) -> (..., nb, k); fold when plus_plus (binary only)."""
    if plus_plus:
        return fold_bin_product(u)
    return jnp.einsum("...bp,pk->...bk", u, pattern_matrix)


def _assemble(r_blocks: jax.Array, m: int) -> jax.Array:
    """(..., nb, k) -> (..., m): concatenate block results, drop col padding."""
    out = r_blocks.reshape(*r_blocks.shape[:-2], -1)
    return out[..., :m]


@partial(jax.jit, static_argnames=("impl", "plus_plus"))
def rsr_matmul_binary(v: jax.Array, idx: BinaryRSRIndex, *,
                      impl: str = "segments",
                      plus_plus: bool = False) -> jax.Array:
    """Algorithm 2 (RSR) / with Algorithm 3 step-2 (RSR++): v · B, v (..., n)."""
    u = _seg_sums(v, idx, 2 ** idx.k, impl)
    r = _block_product(u, binlib.bin_matrix(idx.k, v.dtype), plus_plus)
    return _assemble(r, idx.m)


@partial(jax.jit, static_argnames=("impl", "plus_plus"))
def rsr_matmul_ternary(v: jax.Array, idx: TernaryRSRIndex, *,
                       impl: str = "segments",
                       plus_plus: bool = False) -> jax.Array:
    """Prop 2.1 assembly: v·A = v·B1 − v·B2."""
    pos = rsr_matmul_binary(v, idx.pos, impl=impl, plus_plus=plus_plus)
    neg = rsr_matmul_binary(v, idx.neg, impl=impl, plus_plus=plus_plus)
    return pos - neg


@partial(jax.jit, static_argnames=("impl",))
def rsr_matmul_ternary_direct(v: jax.Array, idx: TernaryDirectIndex, *,
                              impl: str = "segments") -> jax.Array:
    """Beyond-paper single-pass ternary RSR (3^k buckets, Tern_[k] step 2)."""
    u = _seg_sums(v, idx, 3 ** idx.k, impl)
    r = _block_product(u, binlib.tern_matrix(idx.k, v.dtype), plus_plus=False)
    return _assemble(r, idx.m)
