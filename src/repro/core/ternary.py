"""Ternary/binary weight handling (Paper §2, Prop 2.1) + BitNet-style QAT quantizers.

A ternary matrix ``A ∈ {-1,0,1}^{n×m}`` is decomposed as ``A = B1 - B2`` with
``B1 = (A == 1)`` and ``B2 = (A == -1)`` (Proposition 2.1).  All RSR machinery
operates on binary matrices; ternary support is the (B1, B2) pair plus the
beyond-paper ternary-direct code path (see preprocess.py).

Also provides the 2-bit packing used by the dense "Standard" TPU baseline
kernel and the absmean ternary quantizer (BitNet b1.58, Ma et al. 2024) used
for quantization-aware training so trained checkpoints are RSR-preprocessable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "decompose_ternary",
    "recompose_ternary",
    "pack2bit",
    "unpack2bit",
    "absmean_quantize",
    "ste_ternary",
    "absmax_quantize_activations",
]


def decompose_ternary(a: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Proposition 2.1: A = B1 - B2 with binary B1, B2 (same shape, int8)."""
    b1 = (a == 1).astype(jnp.int8)
    b2 = (a == -1).astype(jnp.int8)
    return b1, b2


def recompose_ternary(b1: jax.Array, b2: jax.Array) -> jax.Array:
    """Inverse of :func:`decompose_ternary`."""
    return (b1.astype(jnp.int8) - b2.astype(jnp.int8)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# 2-bit packing (dense baseline storage: the best-practice non-RSR layout)
# ---------------------------------------------------------------------------

def pack2bit(a: jax.Array) -> jax.Array:
    """Pack a ternary array {-1,0,1} into uint8, 4 values per byte.

    Encoding per 2-bit field: 0 -> 0, 1 -> 1, -1 -> 2.  Packing runs along the
    *leading* axis (rows) so a column stays contiguous per packed byte — the
    dequant matmul kernel unpacks 4 rows at a time.
    Input leading dim must be a multiple of 4 (pad first if not).
    """
    n = a.shape[0]
    if n % 4 != 0:
        raise ValueError(f"pack2bit needs leading dim % 4 == 0, got {n}")
    enc = jnp.where(a == -1, 2, a).astype(jnp.uint8)  # {-1,0,1} -> {2,0,1}
    enc = enc.reshape(n // 4, 4, *a.shape[1:])
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8).reshape(
        (1, 4) + (1,) * (a.ndim - 1))
    return jnp.sum(enc << shifts, axis=1).astype(jnp.uint8)


def unpack2bit(packed: jax.Array, n_rows: int) -> jax.Array:
    """Inverse of :func:`pack2bit` -> int8 ternary array with ``n_rows`` rows."""
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8).reshape(
        (1, 4) + (1,) * (packed.ndim - 1))
    fields = (packed[:, None] >> shifts) & 0x3
    dec = jnp.where(fields == 2, -1, fields.astype(jnp.int8)).astype(jnp.int8)
    return dec.reshape(n_rows, *packed.shape[1:])


# ---------------------------------------------------------------------------
# QAT quantizers (training side; BitNet b1.58)
# ---------------------------------------------------------------------------

def absmean_quantize(w: jax.Array, eps: float = 1e-6) -> tuple[jax.Array, jax.Array]:
    """BitNet-b1.58 absmean ternary quantization.

    Returns (ternary int8 matrix, per-matrix fp scale gamma) with
    ``W ≈ gamma * W_t``,  ``W_t = clip(round(W / gamma), -1, 1)``.
    """
    gamma = jnp.mean(jnp.abs(w)) + eps
    wt = jnp.clip(jnp.round(w / gamma), -1, 1).astype(jnp.int8)
    return wt, gamma.astype(jnp.float32)


def ste_ternary(w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Straight-through ternary quantization for QAT forward passes.

    Forward: gamma * clip(round(w/gamma), -1, 1).  Backward: identity.
    """
    gamma = jnp.mean(jnp.abs(w)) + eps
    wq = gamma * jnp.clip(jnp.round(w / gamma), -1, 1)
    return w + jax.lax.stop_gradient(wq - w)


def absmax_quantize_activations(x: jax.Array, bits: int = 8,
                                eps: float = 1e-6) -> jax.Array:
    """Per-token absmax fake-quant of activations (BitNet §2), STE backward."""
    qmax = 2 ** (bits - 1) - 1
    scale = qmax / (jnp.max(jnp.abs(x), axis=-1, keepdims=True) + eps)
    xq = jnp.clip(jnp.round(x * scale), -qmax, qmax) / scale
    return x + jax.lax.stop_gradient(xq - x)


# ---------------------------------------------------------------------------
# Random ternary/binary generators (benchmarks + tests)
# ---------------------------------------------------------------------------

def random_ternary(key: jax.Array, shape, p_zero: float = 1 / 3) -> jax.Array:
    """Random ternary matrix; P(0)=p_zero, P(+1)=P(-1)=(1-p_zero)/2."""
    u = jax.random.uniform(key, shape)
    p1 = (1 - p_zero) / 2
    return jnp.where(u < p1, 1, jnp.where(u < 2 * p1, -1, 0)).astype(jnp.int8)


def random_binary(key: jax.Array, shape, p_one: float = 0.5) -> jax.Array:
    return (jax.random.uniform(key, shape) < p_one).astype(jnp.int8)
