"""Public API facade for the RSR core.

    idx = preprocess(W, k=6, mode="ternary")         # offline, once per model
    y   = rsr_matmul(v, idx, impl="onehot", plus_plus=True)   # inference

``mode``: "binary" (W ∈ {0,1}), "ternary" (Prop 2.1 pair), "ternary_direct"
(beyond-paper base-3).  ``k=None`` picks the paper's optimal k (Eq. 6/7) for
the CPU paths or the roofline-optimal k for the TPU one-hot path.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from repro.core import preprocess as _pp
from repro.core import rsr as _rsr
from repro.core.preprocess import (BinaryRSRIndex, TernaryDirectIndex,
                                   TernaryRSRIndex)

__all__ = ["preprocess", "rsr_matmul", "default_k", "RSR_TPU_K"]

# Roofline-optimal block width for the TPU one-hot kernel (DESIGN.md §2):
# balance 2·(2^k/k) FLOPs/weight-bit against the v5e FLOP:byte ratio.
RSR_TPU_K = 6

AnyIndex = Union[BinaryRSRIndex, TernaryRSRIndex, TernaryDirectIndex]


def default_k(n: int, *, target: str = "tpu", plus_plus: bool = True) -> int:
    """Paper-optimal k for CPU (Eq. 6/7) or roofline-optimal k for TPU."""
    if target == "tpu":
        return RSR_TPU_K
    return _pp.optimal_k_rsrpp(n) if plus_plus else _pp.optimal_k_rsr(n)


def preprocess(w: jax.Array, k: Optional[int] = None, *,
               mode: str = "ternary", target: str = "tpu") -> AnyIndex:
    """Offline index construction (Algorithm 1) for a trained weight matrix."""
    if k is None:
        k = default_k(w.shape[0], target=target)
    if mode == "binary":
        return _pp.preprocess_binary(w, k)
    if mode == "ternary":
        return _pp.preprocess_ternary(w, k)
    if mode == "ternary_direct":
        return _pp.preprocess_ternary_direct(w, k)
    raise ValueError(f"unknown mode {mode!r}")


def rsr_matmul(v: jax.Array, idx: AnyIndex, *, impl: str = "segments",
               plus_plus: bool = False) -> jax.Array:
    """v (..., n) × indexed matrix -> (..., m).  Dispatches on index type."""
    if isinstance(idx, BinaryRSRIndex):
        return _rsr.rsr_matmul_binary(v, idx, impl=impl, plus_plus=plus_plus)
    if isinstance(idx, TernaryRSRIndex):
        return _rsr.rsr_matmul_ternary(v, idx, impl=impl, plus_plus=plus_plus)
    if isinstance(idx, TernaryDirectIndex):
        return _rsr.rsr_matmul_ternary_direct(v, idx, impl=impl)
    raise TypeError(f"unknown index type {type(idx)}")
