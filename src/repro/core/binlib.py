"""Bin_[k] / Tern_[k] pattern-enumeration matrices and code extraction.

``Bin_[k]`` (paper §3.2) is the 2^k × k binary matrix whose row j spells the
k-bit big-endian binary expansion of j, rows in ascending order.  The paper's
Example Bin_[3] drops the all-zero row (a typo); we use the complete 2^k rows —
Lemma 4.2 requires exactly one row per possible pattern.

``Tern_[k]`` (beyond-paper ternary-direct variant) is the 3^k × k ternary
matrix whose row j spells the base-3 big-endian expansion of j with digits
mapped {0,1,2} -> {0,1,-1}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["bin_matrix", "tern_matrix", "binary_row_codes", "ternary_row_codes",
           "code_dtype"]


def code_dtype(num_codes: int):
    """Smallest unsigned integer dtype able to hold codes in [0, num_codes)."""
    if num_codes <= 2 ** 8:
        return jnp.uint8
    if num_codes <= 2 ** 16:
        return jnp.uint16
    return jnp.uint32


@functools.lru_cache(maxsize=None)
def _bin_np(k: int) -> np.ndarray:
    j = np.arange(2 ** k, dtype=np.uint32)[:, None]
    bits = (j >> np.arange(k - 1, -1, -1, dtype=np.uint32)[None, :]) & 1
    return bits.astype(np.int8)


def bin_matrix(k: int, dtype=jnp.float32) -> jax.Array:
    """Bin_[k]: (2^k, k), row j = big-endian bits of j."""
    return jnp.asarray(_bin_np(k), dtype=dtype)


@functools.lru_cache(maxsize=None)
def _tern_np(k: int) -> np.ndarray:
    j = np.arange(3 ** k, dtype=np.int64)[:, None]
    digits = (j // (3 ** np.arange(k - 1, -1, -1, dtype=np.int64))[None, :]) % 3
    return np.where(digits == 2, -1, digits).astype(np.int8)


def tern_matrix(k: int, dtype=jnp.float32) -> jax.Array:
    """Tern_[k]: (3^k, k), row j = big-endian base-3 digits of j, 2 -> -1."""
    return jnp.asarray(_tern_np(k), dtype=dtype)


def binary_row_codes(block: jax.Array) -> jax.Array:
    """Per-row k-bit codes of a binary block (n, k) -> (n,) (Def 3.2 value).

    ``code[r] = Σ_j block[r, j] << (k-1-j)`` — the big-endian binary value the
    paper sorts by.  Works batched over leading dims: (..., n, k) -> (..., n).
    """
    k = block.shape[-1]
    weights = (2 ** jnp.arange(k - 1, -1, -1, dtype=jnp.int32))
    return jnp.sum(block.astype(jnp.int32) * weights, axis=-1)


def ternary_row_codes(block: jax.Array) -> jax.Array:
    """Per-row base-3 codes of a ternary block (..., n, k) -> (..., n).

    Digit mapping {0,1,-1} -> {0,1,2}, big-endian.
    """
    k = block.shape[-1]
    digits = jnp.where(block == -1, 2, block).astype(jnp.int32)
    weights = jnp.asarray(3 ** np.arange(k - 1, -1, -1, dtype=np.int64),
                          dtype=jnp.int32)
    return jnp.sum(digits * weights, axis=-1)
