"""Preprocessing: index construction (Paper §3, Algorithm 1).

Given a binary weight matrix ``B ∈ {0,1}^{n×m}`` (n = input/row dim, m =
output/column dim) and block width ``k``:

  Step 1 (Def 3.1)  column blocking:  ⌈m/k⌉ blocks of k consecutive columns.
  Step 2 (Def 3.2)  binary row order: per block, the stable permutation σ that
                    sorts rows by their k-bit big-endian pattern value.
  Step 3 (Def 3.4)  full segmentation: per block, the length-2^k list L of
                    first indices per pattern value (empty patterns collapse
                    onto the next start, exactly as in the paper's Figure 2).

The index is returned as a :class:`BinaryRSRIndex` pytree carrying BOTH the
paper-faithful (σ, L) representation (drives the CPU/NumPy reference paths and
the memory accounting of Fig. 5) and the packed per-row code array (drives the
TPU one-hot kernel — see DESIGN.md §2; σ = argsort(codes), L = cumsum of the
code histogram, so the two representations are mutually recoverable).

Ternary matrices become a pair of binary indices via Prop 2.1
(:class:`TernaryRSRIndex`) or a single base-3 index (beyond-paper
ternary-direct, :class:`TernaryDirectIndex`).

All functions are jit-able; preprocessing itself is a one-off offline step
(paper: O(n·m), optimal since the input must be read).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binlib, ternary

__all__ = [
    "BinaryRSRIndex", "TernaryRSRIndex", "TernaryDirectIndex",
    "preprocess_binary", "preprocess_ternary", "preprocess_ternary_direct",
    "optimal_k_rsr", "optimal_k_rsrpp", "index_nbytes", "pad_columns",
]


# ---------------------------------------------------------------------------
# Index pytrees
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BinaryRSRIndex:
    """Preprocessed index of a binary matrix B (n×m), block width k.

    codes : (num_blocks, n) uint{8,16,32} — k-bit pattern value of each row in
            each column block (big-endian, Def 3.2).
    perm  : (num_blocks, n) int32 — σ_Bᵢ; argsort of ``codes`` (stable).  Row
            ``perm[i, r]`` of block i is the r-th row in binary row order.
    seg   : (num_blocks, 2^k + 1) int32 — full segmentation with a trailing
            sentinel n; segment j (pattern value j) spans perm rows
            [seg[i, j], seg[i, j+1]).  (The paper's L is seg[..., :-1],
            1-indexed; we use 0-indexed with sentinel for vector math.)
    """
    codes: jax.Array
    perm: jax.Array
    seg: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.codes.shape[0]

    @property
    def m_padded(self) -> int:
        return self.num_blocks * self.k


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TernaryRSRIndex:
    """Prop 2.1 pair: A = B1 - B2, each side a BinaryRSRIndex."""
    pos: BinaryRSRIndex   # B1 = (A == +1)
    neg: BinaryRSRIndex   # B2 = (A == -1)

    @property
    def k(self) -> int:
        return self.pos.k

    @property
    def n(self) -> int:
        return self.pos.n

    @property
    def m(self) -> int:
        return self.pos.m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TernaryDirectIndex:
    """Beyond-paper: single base-3 index (3^k buckets, one pass instead of two).

    codes : (num_blocks, n) uint{8,16,32} — base-3 pattern value per row/block.
    perm/seg : analogous to BinaryRSRIndex with 3^k segments.
    """
    codes: jax.Array
    perm: jax.Array
    seg: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.codes.shape[0]


# ---------------------------------------------------------------------------
# Preprocessing (Algorithm 1)
# ---------------------------------------------------------------------------

def pad_columns(b: jax.Array, k: int) -> jax.Array:
    """Zero-pad trailing columns so m is a multiple of k (zero cols are inert:
    they map to pattern bits 0 and their outputs are sliced away)."""
    m = b.shape[1]
    pad = (-m) % k
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
    return b


def _blocks_of(b: jax.Array, k: int) -> jax.Array:
    """(n, m_pad) -> (num_blocks, n, k) contiguous column blocks (Def 3.1)."""
    n, mp = b.shape
    return b.reshape(n, mp // k, k).transpose(1, 0, 2)


def _segments_from_codes(codes: jax.Array, num_patterns: int, n: int):
    """σ and L from per-row codes: σ = stable argsort; L via histogram cumsum.

    Full segmentation semantics (paper Fig. 2): L[j] = first sorted position
    whose pattern value is j; empty patterns take the next segment's start.
    That is exactly the exclusive cumulative histogram.
    """
    perm = jnp.argsort(codes, axis=-1, stable=True).astype(jnp.int32)
    hist = jax.vmap(
        lambda c: jnp.bincount(c.astype(jnp.int32), length=num_patterns))(codes)
    seg = jnp.concatenate(
        [jnp.zeros((codes.shape[0], 1), jnp.int32),
         jnp.cumsum(hist, axis=-1, dtype=jnp.int32)], axis=-1)
    return perm, seg


def preprocess_binary(b: jax.Array, k: int) -> BinaryRSRIndex:
    """Algorithm 1 for a binary matrix (n×m) with block width k."""
    n, m = b.shape
    blocks = _blocks_of(pad_columns(b, k), k)            # (nb, n, k)
    codes = binlib.binary_row_codes(blocks)              # (nb, n) int32
    perm, seg = _segments_from_codes(codes, 2 ** k, n)
    codes = codes.astype(binlib.code_dtype(2 ** k))
    return BinaryRSRIndex(codes=codes, perm=perm, seg=seg, k=k, n=n, m=m)


def preprocess_ternary(a: jax.Array, k: int) -> TernaryRSRIndex:
    """Prop 2.1 + Algorithm 1 on both binary parts."""
    b1, b2 = ternary.decompose_ternary(a)
    return TernaryRSRIndex(pos=preprocess_binary(b1, k),
                           neg=preprocess_binary(b2, k))


def preprocess_ternary_direct(a: jax.Array, k: int) -> TernaryDirectIndex:
    """Beyond-paper single-pass ternary index (3^k patterns)."""
    n, m = a.shape
    blocks = _blocks_of(pad_columns(a, k), k)
    codes = binlib.ternary_row_codes(blocks)
    perm, seg = _segments_from_codes(codes, 3 ** k, n)
    codes = codes.astype(binlib.code_dtype(3 ** k))
    return TernaryDirectIndex(codes=codes, perm=perm, seg=seg, k=k, n=n, m=m)


# ---------------------------------------------------------------------------
# Optimal k (paper §4.2.2 / §4.3.2, Eq. 6 / Eq. 7)
# ---------------------------------------------------------------------------

def _argmin_cost(n: int, costf, k_max: int) -> int:
    ks = range(1, max(2, k_max + 1))
    return min(ks, key=lambda k: costf(n, k))


def optimal_k_rsr(n: int) -> int:
    """argmin_k (n/k)(n + k·2^k), k ∈ [1, log n − log log n] (Eq. 6)."""
    k_max = max(1, int(math.log2(max(2.0, n / max(1.0, math.log2(n))))))
    return _argmin_cost(n, lambda n_, k: (n_ / k) * (n_ + k * 2 ** k), k_max)


def optimal_k_rsrpp(n: int) -> int:
    """argmin_k (n/k)(n + 2^k), k ∈ [1, log n] (Eq. 7)."""
    k_max = max(1, int(math.log2(n)))
    return _argmin_cost(n, lambda n_, k: (n_ / k) * (n_ + 2 ** k), k_max)


# ---------------------------------------------------------------------------
# Space accounting (Theorem 3.6 / Fig. 5)
# ---------------------------------------------------------------------------

def index_nbytes(idx, representation: str = "paper") -> int:
    """Bytes to store the index.

    representation="paper": σ + L per block (what the paper's Fig. 5 stores).
    representation="codes": packed code array only (what the TPU kernel reads).
    """
    def one(b: BinaryRSRIndex | TernaryDirectIndex) -> int:
        if representation == "paper":
            return b.perm.size * b.perm.dtype.itemsize + \
                   b.seg.size * b.seg.dtype.itemsize
        return b.codes.size * b.codes.dtype.itemsize

    if isinstance(idx, TernaryRSRIndex):
        return one(idx.pos) + one(idx.neg)
    return one(idx)
