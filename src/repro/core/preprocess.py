"""Preprocessing: index construction (Paper §3, Algorithm 1).

Given a binary weight matrix ``B ∈ {0,1}^{n×m}`` (n = input/row dim, m =
output/column dim) and block width ``k``:

  Step 1 (Def 3.1)  column blocking:  ⌈m/k⌉ blocks of k consecutive columns.
  Step 2 (Def 3.2)  binary row order: per block, the stable permutation σ that
                    sorts rows by their k-bit big-endian pattern value.
  Step 3 (Def 3.4)  full segmentation: per block, the length-2^k list L of
                    first indices per pattern value (empty patterns collapse
                    onto the next start, exactly as in the paper's Figure 2).

The index is returned as a :class:`BinaryRSRIndex` pytree carrying BOTH the
paper-faithful (σ, L) representation (drives the CPU/NumPy reference paths and
the memory accounting of Fig. 5) and the packed per-row code array (drives the
TPU one-hot kernel — see DESIGN.md §2; σ = argsort(codes), L = cumsum of the
code histogram, so the two representations are mutually recoverable).

Ternary matrices become a pair of binary indices via Prop 2.1
(:class:`TernaryRSRIndex`) or a single base-3 index (beyond-paper
ternary-direct, :class:`TernaryDirectIndex`).

All functions are jit-able; preprocessing itself is a one-off offline step
(paper: O(n·m), optimal since the input must be read).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binlib, ternary

__all__ = [
    "BinaryRSRIndex", "TernaryRSRIndex", "TernaryDirectIndex",
    "preprocess_binary", "preprocess_ternary", "preprocess_ternary_direct",
    "optimal_k_rsr", "optimal_k_rsrpp", "index_nbytes", "pad_columns",
    "pack_code_words", "unpack_code_words", "code_traffic_bits_per_weight",
]


# ---------------------------------------------------------------------------
# Index pytrees
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BinaryRSRIndex:
    """Preprocessed index of a binary matrix B (n×m), block width k.

    codes : (num_blocks, n) uint{8,16,32} — k-bit pattern value of each row in
            each column block (big-endian, Def 3.2).
    perm  : (num_blocks, n) int32 — σ_Bᵢ; argsort of ``codes`` (stable).  Row
            ``perm[i, r]`` of block i is the r-th row in binary row order.
    seg   : (num_blocks, 2^k + 1) int32 — full segmentation with a trailing
            sentinel n; segment j (pattern value j) spans perm rows
            [seg[i, j], seg[i, j+1]).  (The paper's L is seg[..., :-1],
            1-indexed; we use 0-indexed with sentinel for vector math.)
    """
    codes: jax.Array
    perm: jax.Array
    seg: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.codes.shape[0]

    @property
    def m_padded(self) -> int:
        return self.num_blocks * self.k


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TernaryRSRIndex:
    """Prop 2.1 pair: A = B1 - B2, each side a BinaryRSRIndex."""
    pos: BinaryRSRIndex   # B1 = (A == +1)
    neg: BinaryRSRIndex   # B2 = (A == -1)

    @property
    def k(self) -> int:
        return self.pos.k

    @property
    def n(self) -> int:
        return self.pos.n

    @property
    def m(self) -> int:
        return self.pos.m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TernaryDirectIndex:
    """Beyond-paper: single base-3 index (3^k buckets, one pass instead of two).

    codes : (num_blocks, n) uint{8,16,32} — base-3 pattern value per row/block.
    perm/seg : analogous to BinaryRSRIndex with 3^k segments.
    """
    codes: jax.Array
    perm: jax.Array
    seg: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.codes.shape[0]


# ---------------------------------------------------------------------------
# Preprocessing (Algorithm 1)
# ---------------------------------------------------------------------------

def pad_columns(b: jax.Array, k: int) -> jax.Array:
    """Zero-pad trailing columns so m is a multiple of k (zero cols are inert:
    they map to pattern bits 0 and their outputs are sliced away)."""
    m = b.shape[1]
    pad = (-m) % k
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
    return b


def _blocks_of(b: jax.Array, k: int) -> jax.Array:
    """(n, m_pad) -> (num_blocks, n, k) contiguous column blocks (Def 3.1)."""
    n, mp = b.shape
    return b.reshape(n, mp // k, k).transpose(1, 0, 2)


def _segments_from_codes(codes: jax.Array, num_patterns: int, n: int):
    """σ and L from per-row codes: σ = stable argsort; L via histogram cumsum.

    Full segmentation semantics (paper Fig. 2): L[j] = first sorted position
    whose pattern value is j; empty patterns take the next segment's start.
    That is exactly the exclusive cumulative histogram.
    """
    perm = jnp.argsort(codes, axis=-1, stable=True).astype(jnp.int32)
    hist = jax.vmap(
        lambda c: jnp.bincount(c.astype(jnp.int32), length=num_patterns))(codes)
    seg = jnp.concatenate(
        [jnp.zeros((codes.shape[0], 1), jnp.int32),
         jnp.cumsum(hist, axis=-1, dtype=jnp.int32)], axis=-1)
    return perm, seg


def preprocess_binary(b: jax.Array, k: int) -> BinaryRSRIndex:
    """Algorithm 1 for a binary matrix (n×m) with block width k."""
    n, m = b.shape
    blocks = _blocks_of(pad_columns(b, k), k)            # (nb, n, k)
    codes = binlib.binary_row_codes(blocks)              # (nb, n) int32
    perm, seg = _segments_from_codes(codes, 2 ** k, n)
    codes = codes.astype(binlib.code_dtype(2 ** k))
    return BinaryRSRIndex(codes=codes, perm=perm, seg=seg, k=k, n=n, m=m)


def preprocess_ternary(a: jax.Array, k: int) -> TernaryRSRIndex:
    """Prop 2.1 + Algorithm 1 on both binary parts."""
    b1, b2 = ternary.decompose_ternary(a)
    return TernaryRSRIndex(pos=preprocess_binary(b1, k),
                           neg=preprocess_binary(b2, k))


def preprocess_ternary_direct(a: jax.Array, k: int) -> TernaryDirectIndex:
    """Beyond-paper single-pass ternary index (3^k patterns)."""
    n, m = a.shape
    blocks = _blocks_of(pad_columns(a, k), k)
    codes = binlib.ternary_row_codes(blocks)
    perm, seg = _segments_from_codes(codes, 3 ** k, n)
    codes = codes.astype(binlib.code_dtype(3 ** k))
    return TernaryDirectIndex(codes=codes, perm=perm, seg=seg, k=k, n=n, m=m)


# ---------------------------------------------------------------------------
# Packed-code streaming (serve-path HBM layout)
# ---------------------------------------------------------------------------
#
# The per-row code arrays are uint8/uint16, but narrow integer arrays are a
# poor HBM streaming format on TPU: Mosaic widens sub-32-bit lanes (and int8
# sublane tiling pads to 32 rows), so an unpacked uint8 code stream costs
# ≥8 bits per code word of traffic and often 32.  Packing 4 uint8 (or 2
# uint16) codes per uint32 word along the contraction axis makes the streamed
# bits exactly 8·itemsize per code = 8·itemsize/k bits per weight — 1.6
# bits/weight at the serve default k=5 — and the kernel unpacks in-register
# (shift+mask, VPU) right before building the one-hot.  Packing happens here,
# once, offline, like the rest of Algorithm 1.

def pack_code_words(codes: jax.Array) -> jax.Array:
    """(nb, n) uint8/uint16 codes -> (nb, ceil(n/per)) uint32 words.

    per = 4 // itemsize codes per word, little-endian within the word (code j
    of a word occupies bits [j·8·itemsize, (j+1)·8·itemsize)).  The trailing
    partial word is zero-padded — safe because every consumer zero-pads the
    matching activation rows, so the padded codes' buckets accumulate 0.
    """
    itemsize = jnp.dtype(codes.dtype).itemsize
    assert itemsize in (1, 2), codes.dtype
    per = 4 // itemsize
    nb, n = codes.shape
    pad = (-n) % per
    c = jnp.pad(codes, ((0, 0), (0, pad))).astype(jnp.uint32)
    c = c.reshape(nb, -1, per)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * (8 * itemsize))[None, None]
    # disjoint bitfields: sum == bitwise-or
    return jnp.sum(c << shifts, axis=-1, dtype=jnp.uint32)


def unpack_code_words(words: jax.Array, n: int, code_bits: int) -> jax.Array:
    """Inverse of pack_code_words (host-side oracle; the kernel's in-register
    unpack is the same shift+mask)."""
    per = 32 // code_bits
    mask = jnp.uint32((1 << code_bits) - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * code_bits)[None, None]
    codes = (words[:, :, None] >> shifts) & mask
    return codes.reshape(words.shape[0], -1)[:, :n]


def code_traffic_bits_per_weight(k: int, *, code_itemsize: int = 1,
                                 packed: bool = True,
                                 num_arrays: int = 1) -> float:
    """Weight-side HBM bits per represented weight for the one-hot kernel.

    packed: 8·itemsize bits per code (the uint32 words carry no padding
    beyond the trailing partial word); unpacked: 32 bits per code (Mosaic
    i32 lane widening, the pessimistic honest number).  A code covers k
    weights; ternary-fused streams num_arrays=2 code arrays.
    """
    bits_per_code = 8 * code_itemsize if packed else 32
    return num_arrays * bits_per_code / k


# ---------------------------------------------------------------------------
# Optimal k (paper §4.2.2 / §4.3.2, Eq. 6 / Eq. 7)
# ---------------------------------------------------------------------------

def _argmin_cost(n: int, costf, k_max: int) -> int:
    ks = range(1, max(2, k_max + 1))
    return min(ks, key=lambda k: costf(n, k))


def optimal_k_rsr(n: int) -> int:
    """argmin_k (n/k)(n + k·2^k), k ∈ [1, log n − log log n] (Eq. 6)."""
    k_max = max(1, int(math.log2(max(2.0, n / max(1.0, math.log2(n))))))
    return _argmin_cost(n, lambda n_, k: (n_ / k) * (n_ + k * 2 ** k), k_max)


def optimal_k_rsrpp(n: int) -> int:
    """argmin_k (n/k)(n + 2^k), k ∈ [1, log n] (Eq. 7)."""
    k_max = max(1, int(math.log2(n)))
    return _argmin_cost(n, lambda n_, k: (n_ / k) * (n_ + 2 ** k), k_max)


# ---------------------------------------------------------------------------
# Space accounting (Theorem 3.6 / Fig. 5)
# ---------------------------------------------------------------------------

def index_nbytes(idx, representation: str = "paper") -> int:
    """Bytes to store the index.

    representation="paper": σ + L per block (what the paper's Fig. 5 stores).
    representation="codes": packed code array only (what the TPU kernel reads).
    """
    def one(b: BinaryRSRIndex | TernaryDirectIndex) -> int:
        if representation == "paper":
            return b.perm.size * b.perm.dtype.itemsize + \
                   b.seg.size * b.seg.dtype.itemsize
        return b.codes.size * b.codes.dtype.itemsize

    if isinstance(idx, TernaryRSRIndex):
        return one(idx.pos) + one(idx.neg)
    return one(idx)
