"""Finding / baseline machinery shared by every reprolint checker.

A finding's ``key`` (``CODE:path:symbol``) deliberately excludes line
numbers so a suppression survives unrelated edits; ``symbol`` is whatever
stable anchor the checker owns (function name, config name, env var, ...).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, Optional

__all__ = ["Finding", "load_baseline", "save_baseline", "split_findings",
           "format_report", "BASELINE_ENV", "default_baseline_path"]

BASELINE_ENV = "REPRO_ANALYSIS_BASELINE"
BASELINE_SCHEMA = "reprolint_baseline_v1"


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str          # "RL101"
    path: str          # repo-relative file ("" for tree-level findings)
    symbol: str        # stable anchor within path (baseline fingerprint)
    message: str
    line: int = 0      # 1-based; 0 when not tied to a line

    @property
    def key(self) -> str:
        return f"{self.code}:{self.path}:{self.symbol}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else (self.path or "-")
        return f"{self.code} {loc} [{self.symbol}] {self.message}"


def default_baseline_path(root: str) -> str:
    """$REPRO_ANALYSIS_BASELINE > <root>/reprolint_baseline.json."""
    return (os.environ.get(BASELINE_ENV, "").strip()
            or os.path.join(root, "reprolint_baseline.json"))


def load_baseline(path: str) -> dict[str, str]:
    """{finding key -> justification}; missing file is an empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"{path}: expected schema {BASELINE_SCHEMA!r}, "
                         f"got {payload.get('schema')!r}")
    out: dict[str, str] = {}
    for e in payload.get("suppressions", []):
        key, just = e.get("key"), e.get("justification", "").strip()
        if not key or not just or just.lower().startswith("todo"):
            raise ValueError(
                f"{path}: every suppression needs a 'key' and a non-empty, "
                f"non-TODO 'justification' (offending entry: {e!r})")
        out[key] = just
    return out


def save_baseline(path: str, findings: Iterable[Finding],
                  previous: Optional[dict[str, str]] = None) -> str:
    """Write the baseline for ``findings``; justifications already present
    in ``previous`` are preserved, new keys get a fill-me-in marker the
    loader rejects until a human writes the reason."""
    previous = previous or {}
    entries = []
    seen = set()
    for f in sorted(findings, key=lambda f: f.key):
        if f.key in seen:
            continue
        seen.add(f.key)
        entries.append({
            "key": f.key,
            "justification": previous.get(f.key, "TODO: justify"),
            "message": f.message,
        })
    with open(path, "w") as fp:
        json.dump({"schema": BASELINE_SCHEMA, "suppressions": entries},
                  fp, indent=1, sort_keys=True)
        fp.write("\n")
    return path


def split_findings(findings: Iterable[Finding], baseline: dict[str, str]
                   ) -> tuple[list[Finding], list[Finding], list[str]]:
    """(new, suppressed, stale-baseline-keys)."""
    findings = list(findings)
    keys = {f.key for f in findings}
    new = [f for f in findings if f.key not in baseline]
    suppressed = [f for f in findings if f.key in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return new, suppressed, stale


def format_report(new, suppressed, stale) -> str:
    lines = []
    if new:
        lines.append(f"reprolint: {len(new)} finding(s)")
        for f in sorted(new, key=lambda f: (f.code, f.path, f.line)):
            lines.append("  " + f.render())
    else:
        lines.append("reprolint: no new findings")
    if suppressed:
        lines.append(f"  ({len(suppressed)} baselined finding(s) "
                     f"suppressed)")
    for k in stale:
        lines.append(f"  warning: stale baseline entry (no longer fires): "
                     f"{k}")
    return "\n".join(lines)
