"""Declared contracts the reprolint checkers enforce.

This is the one place the analysis encodes repo knowledge that is not
recoverable from the AST alone: which attributes are host-side scheduler
state, which functions are trace roots, which identifiers carry packed
code words, and the canonical serve geometry the tile checker probes the
config zoo under.  Growing the serve plane?  Extend these sets — the
checkers themselves never need to change.
"""
from __future__ import annotations

# --- host/device boundary (repro.serve) ------------------------------------

#: Attribute names that are host-side scheduler/allocator state by contract:
#: plain Python/NumPy, never traced.  BlockPool internals (paging.py), the
#: Engine's host block-table mirror, and the scheduler position mirror.
HOST_STATE_ATTRS = frozenset({
    # BlockPool (serve/paging.py)
    "_free", "_ref", "_hash_to_bid", "_bid_to_hash", "_warm",
    # Engine host block tables (serve/engine.py)
    "_tables",
    # scheduler position mirror (serve/engine.py / serve/frontend.py)
    "_pos",
})

#: Call names that legitimately carry a value across the host/device
#: boundary: a jnp value wrapped in one of these is materialized to host
#: (or a host value is explicitly converted for device use).
BOUNDARY_WRAPPERS = frozenset({
    "device_get",          # jax.device_get
    "asarray", "array",    # np.asarray / np.array (host side)
    "int", "float", "list", "tuple",
})

#: jnp functions that merely CONSTRUCT/convert (host -> device) rather than
#: compute; these may take host-state values as input.
JNP_CONVERTERS = frozenset({
    "asarray", "array", "int32", "int64", "uint32", "float32", "zeros",
    "ones", "full", "arange", "dtype",
})

#: file (repo-relative) -> function names traced by jit at their call sites
#: (the Engine jits lambdas over these; the AST cannot see that).  Pallas
#: kernel bodies and @jax.jit functions are detected automatically.
TRACE_ROOTS = {
    "src/repro/models/transformer.py": frozenset({
        "forward", "loss_fn", "prefill_step", "decode_step",
        "slot_cache", "update_slot_cache", "adopt_pools", "copy_pool_block",
    }),
}

#: directories (repo-relative) scanned per checker direction
SERVE_DIRS = ("src/repro/serve",)
TRACED_DIRS = ("src/repro/kernels", "src/repro/models")

# --- quantized dtype path (repro.core.preprocess -> kernels) ----------------

#: identifiers whose values carry packed/unpacked code words; taint seeds.
CODE_WORD_NAMES = frozenset({
    "codes", "packed", "words", "neg_codes", "code_words",
    "codes_ref", "neg_ref",
})

#: functions whose return value carries code words.
CODE_WORD_PRODUCERS = frozenset({
    "pack_code_words", "unpack_code_words", "_unpack_words",
    "binary_row_codes", "ternary_row_codes",
})

#: identifiers carrying the absmean dequant scale (must stay f32).
SCALE_NAMES = frozenset({"scale", "gamma", "scale_ref"})

#: files on the packed-code path the dtype-flow checker scans.
DTYPE_FLOW_DIRS = ("src/repro/core", "src/repro/kernels",
                   "src/repro/models")

# --- env registry -----------------------------------------------------------

#: the documented env table lives in this module's docstring.
ENV_TABLE_FILE = "src/repro/serve/__init__.py"
ENV_PREFIX = "REPRO_"
ENV_SCAN_DIRS = ("src",)

# --- metric registry --------------------------------------------------------

#: the metric name catalog shares the serve module docstring with the env
#: table (Observability section).
METRIC_CATALOG_FILE = ENV_TABLE_FILE

#: name prefixes that make a string a telemetry metric name; anything a
#: metric constructor gets that starts with one of these must be
#: catalogued.
METRIC_PREFIXES = ("serve_", "rsr_")

#: call names (plain or attribute) whose first string argument is a
#: metric family name: the repro.serve.telemetry constructors and the
#: registry/Telemetry get-or-create passthroughs.
METRIC_CALLS = frozenset({
    "counter", "gauge", "histogram", "stats_counters",
    "Counter", "Gauge", "Histogram", "StatsView",
})

#: directories scanned for metric emissions.
METRIC_SCAN_DIRS = ("src",)

# --- tile / VMEM probing geometry -------------------------------------------

#: canonical serve geometry the tile checker evaluates the zoo under —
#: mirrors the benchmark/test serve settings (benchmarks/run.py): paged KV
#: with 16-token blocks, batch 8, 32-token prefill chunks.
ANALYSIS_BATCH = 8
ANALYSIS_PREFILL_CHUNK = 32
ANALYSIS_KV_BLOCK = 16
ANALYSIS_MAX_SEQ = 4096

#: flattened batch-row counts a serve engine can put through a quantized
#: linear: single-row decode, full-batch decode, and the chunked-prefill
#: row block.
def probe_rows() -> tuple[int, ...]:
    return (1, ANALYSIS_BATCH, ANALYSIS_BATCH * ANALYSIS_PREFILL_CHUNK)


#: query-chunk sizes the paged-attention kernel can see: decode (C == 1)
#: and the prefill chunk.
def probe_chunks() -> tuple[int, ...]:
    return (1, ANALYSIS_PREFILL_CHUNK)
