"""RL3xx — quantized dtype-flow checker (pure AST, nothing imported).

Follows the packed-code path (``core.preprocess.pack_code_words`` ->
``kernels.dispatch`` -> ``kernels.rsr_onehot``) with a per-function taint
pass: values rooted in a code-word identifier (``contracts
.CODE_WORD_NAMES``), a producer call (``contracts.CODE_WORD_PRODUCERS``),
or a ``p["codes"]``-style access carry integer code words and must never
be cast or promoted to floating point — a float round-trip silently
corrupts packed base-3 words above 2**24 and doubles the stream's
bandwidth (RL301).  Comparisons launder taint: the kernels' one-hot
construction ``(codes == iota).astype(f32)`` casts the *boolean*, which
is the supported pattern.  Dequant scales (``contracts.SCALE_NAMES``)
must stay float32 — a half-precision scale quantizes the per-block
absmean and shows up as model-quality drift, not a crash (RL302).
"""
from __future__ import annotations

import ast
import os

from repro.analysis import contracts
from repro.analysis.findings import Finding

__all__ = ["check", "check_source"]

_FLOAT_DTYPES = frozenset({
    "float", "float16", "float32", "float64", "bfloat16", "half", "single",
    "double",
})
_NARROW_FLOATS = frozenset({"float16", "bfloat16", "half"})


def _dtype_token(node: ast.AST) -> str | None:
    """The dtype an AST expression names, if recognizable."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):   # jnp.float32, np.float16, ...
        return node.attr
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Call):        # jnp.dtype("float32")
        for a in node.args:
            t = _dtype_token(a)
            if t:
                return t
    return None


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


class _Taint:
    """Is this expression rooted in code words / a scale value?"""

    def __init__(self, code_vars: set[str], scale_vars: set[str]):
        self.code_vars = code_vars
        self.scale_vars = scale_vars

    def _rooted(self, node: ast.AST, names, producers) -> bool:
        if isinstance(node, ast.Compare):
            return False           # comparisons produce booleans: taint ends
        if isinstance(node, ast.Name):
            return node.id in names
        if isinstance(node, ast.Attribute):
            return node.attr in names or self._rooted(node.value, names,
                                                      producers)
        if isinstance(node, ast.Subscript):
            if (isinstance(node.slice, ast.Constant)
                    and node.slice.value in names):
                return True        # p["codes"]
            return self._rooted(node.value, names, producers)
        if isinstance(node, ast.Call):
            n = _call_name(node)
            if n in producers:
                return True
            if n in ("astype", "reshape", "ravel", "transpose", "pad",
                     "concatenate", "where", "squeeze"):
                # shape ops / casts forward the taint of their operand
                inner = (node.func.value
                         if isinstance(node.func, ast.Attribute)
                         else (node.args[0] if node.args else None))
                return inner is not None and self._rooted(inner, names,
                                                          producers)
            return False
        if isinstance(node, (ast.BinOp,)):
            return (self._rooted(node.left, names, producers)
                    or self._rooted(node.right, names, producers))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self._rooted(e, names, producers) for e in node.elts)
        return False

    def code(self, node: ast.AST) -> bool:
        return self._rooted(node, self.code_vars,
                            contracts.CODE_WORD_PRODUCERS)

    def scale(self, node: ast.AST) -> bool:
        return self._rooted(node, self.scale_vars, frozenset())


def _scopes(tree: ast.Module):
    fns = [n for n in ast.walk(tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    yield "<module>", tree.body
    for fn in fns:
        yield fn.name, fn.body


def _scope_walk(body):
    """Walk a scope's statements without descending into nested function
    scopes (those are visited as their own ``_scopes`` entries)."""
    stack = list(body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _collect_vars(body) -> tuple[set[str], set[str]]:
    """Names in this scope carrying code words / scales (declared seeds +
    anything assigned from a tainted expression, to fixpoint)."""
    code = set(contracts.CODE_WORD_NAMES)
    scale = set(contracts.SCALE_NAMES)
    for _ in range(3):              # tiny fixpoint: chains are short
        t = _Taint(code, scale)
        grew = False
        for node in _scope_walk(body):
            if isinstance(node, ast.Assign) and node.value is not None:
                names = {tgt.id for tgt in node.targets
                         if isinstance(tgt, ast.Name)}
                if names and t.code(node.value) and not names <= code:
                    code |= names
                    grew = True
                if names and t.scale(node.value) and not names <= scale:
                    scale |= names
                    grew = True
        if not grew:
            break
    return code, scale


def check_source(rel_path: str, source: str) -> list[Finding]:
    findings = []
    tree = ast.parse(source)
    for scope_name, body in _scopes(tree):
        code_vars, scale_vars = _collect_vars(body)
        taint = _Taint(code_vars, scale_vars)
        for node in _scope_walk(body):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            # receiver.astype(dtype) / jnp.asarray(x, dtype) /
            # jnp.float32(x)
            dtype = None
            operand = None
            if name == "astype" and isinstance(node.func, ast.Attribute):
                operand = node.func.value
                dtype = _dtype_token(node.args[0]) if node.args else None
            elif name in ("asarray", "array", "full_like", "zeros_like"):
                operand = node.args[0] if node.args else None
                for i, a in enumerate(node.args[1:], 1):
                    dtype = dtype or _dtype_token(a)
                for kw in node.keywords:
                    if kw.arg == "dtype":
                        dtype = _dtype_token(kw.value)
            elif name in _FLOAT_DTYPES and node.args:
                operand, dtype = node.args[0], name
            if operand is None or dtype is None:
                continue
            if dtype in _FLOAT_DTYPES and taint.code(operand):
                findings.append(Finding(
                    "RL301", rel_path, f"{scope_name}:{dtype}",
                    f"packed/unpacked code words cast to {dtype} in "
                    f"{scope_name} — code words are exact integers; a "
                    f"float round-trip corrupts packed words above "
                    f"2**24 and doubles stream bandwidth",
                    line=node.lineno))
            elif dtype in _NARROW_FLOATS and taint.scale(operand):
                findings.append(Finding(
                    "RL302", rel_path, f"{scope_name}:{dtype}",
                    f"dequant scale narrowed to {dtype} in "
                    f"{scope_name} — scales are float32 by contract; "
                    f"half-precision absmean scales show up as silent "
                    f"model-quality drift",
                    line=node.lineno))
    return findings


def check(root: str) -> list[Finding]:
    findings = []
    for rel in contracts.DTYPE_FLOW_DIRS:
        base = os.path.join(root, rel)
        for dirpath, _, names in os.walk(base):
            for n in sorted(names):
                if not n.endswith(".py"):
                    continue
                path = os.path.join(dirpath, n)
                rel_path = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path) as f:
                    findings.extend(check_source(rel_path, f.read()))
    return findings
