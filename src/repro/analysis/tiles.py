"""RL1xx — tile / VMEM / regime-coverage checker.

Evaluates the RSR tile tables (``kernels.dispatch.AUTOTUNE_TABLE`` +
``TUNED_TILES``) and the paged-attention query-tile tables
(``kernels.paged_attention.PAGED_ATTN_TILES`` + ``TUNED_ATTN_TILES``),
with the ``autotune_cache.json`` overlay, against every config in the
zoo (``repro.config.list_archs``): every quantized serve linear's
``(nb, n)`` shape is extracted from the ABSTRACT serve tree
(``jax.eval_shape`` over init + serve conversion — zero allocation, the
exact shapes the engine runs), and every paged-attention geometry from
the config's cache layout.  Each probed (shape × batch-row regime) must
have a covering regime entry whose post-clamp tiles respect TPU tiling
quanta and whose kernel-launch working set fits the per-kernel VMEM
budget (``roofline.hw``).  The VMEM model mirrors the actual kernel
layouts in ``kernels/rsr_onehot.py`` and ``kernels/paged_attention.py``:
double-buffered operand/output block tiles + VMEM scratch + the largest
resident intermediate.
"""
from __future__ import annotations

import functools
import json
import os

from repro.analysis import contracts
from repro.analysis.findings import Finding
from repro.roofline import hw

__all__ = ["check", "rsr_workset_bytes", "gqa_workset_bytes",
           "mla_workset_bytes", "check_rsr_shape", "check_attn_geometry"]

_F32 = 4


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _bucket(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


# ---------------------------------------------------------------------------
# VMEM working-set models (mirror the kernel layouts)
# ---------------------------------------------------------------------------

def rsr_workset_bytes(tiles: tuple[int, int, int], k: int,
                      code_itemsize: int = 1) -> int:
    """rsr_onehot_matmul launch working set for (tile_b, tile_blk, tile_n):
    2x-buffered in/out block tiles + the (TBLK, TB, P) accumulator scratch
    + the per-iteration (TN, P) one-hot/iota intermediates."""
    tb, tblk, tn = tiles
    p = 3 ** k
    per = 4 // code_itemsize
    ins = (tb * tn * _F32                  # x tile (f32 by dispatch)
           + 2 * tblk * (tn // per) * 4    # packed codes + neg words (u32)
           + p * k * _F32                  # pattern
           + _F32                          # scale
           + tblk * k * _F32)              # bias tile
    out = tb * tblk * k * _F32
    scratch = tblk * tb * p * _F32
    inter = 2 * tn * p * _F32              # iota + one one-hot tile
    return 2 * (ins + out) + scratch + inter


def gqa_workset_bytes(tile_c: int, heads: int, kv_heads: int, head_dim: int,
                      block_size: int, cache_itemsize: int) -> int:
    """paged_gqa_attend launch working set for one grid step."""
    groups = max(1, heads // max(1, kv_heads))
    ins = (tile_c * heads * head_dim * cache_itemsize        # q tile
           + 2 * kv_heads * block_size * head_dim * cache_itemsize  # k, v
           + tile_c * 4)                                     # positions
    out = tile_c * heads * head_dim * _F32
    scratch = kv_heads * tile_c * groups * (2 + head_dim) * _F32  # m, l, acc
    return 2 * (ins + out) + scratch


def mla_workset_bytes(tile_c: int, heads: int, rank: int, rope_dim: int,
                      block_size: int, cache_itemsize: int) -> int:
    """paged_mla_attend launch working set for one grid step."""
    ins = (tile_c * heads * rank * cache_itemsize            # q_lat
           + tile_c * heads * rope_dim * cache_itemsize      # q_pe
           + block_size * (rank + rope_dim) * cache_itemsize  # c, pe pools
           + tile_c * 4)                                     # positions
    out = tile_c * heads * rank * _F32
    scratch = tile_c * heads * (2 + rank) * _F32             # m, l, acc
    return 2 * (ins + out) + scratch


# ---------------------------------------------------------------------------
# Table resolution (mirrors dispatch.select_tiles / select_attn_tiles,
# but over injectable tables so the overlay file can be checked offline)
# ---------------------------------------------------------------------------

def _rsr_regime(b: int, table) -> str | None:
    for name, max_b, *_ in table:
        if max_b is None or b <= max_b:
            return name
    return None


def _rsr_tiles(b: int, nb: int, n: int, table, tuned):
    regime = _rsr_regime(b, table)
    if regime is None:
        return None, None
    tuned_t = tuned.get((regime, _bucket(nb), _bucket(n)))
    if tuned_t is not None:
        tile_b, tile_blk, tile_n = tuned_t
    else:
        for _, max_b, tile_b, tile_blk, tile_n in table:
            if max_b is None or b <= max_b:
                break
    tile_b = min(tile_b, _round_up(b, 8))
    tile_blk = min(tile_blk, _round_up(nb, 8))
    tile_n = min(tile_n, _round_up(n, 128))
    return regime, (tile_b, tile_blk, tile_n)


def _attn_regime(c: int, table) -> str | None:
    for name, max_c, *_ in table:
        if max_c is None or c <= max_c:
            return name
    return None


def _attn_tile(c: int, table, tuned):
    regime = _attn_regime(c, table)
    if regime is None:
        return None, None
    tuned_t = tuned.get((regime, _bucket(c)))
    if tuned_t is not None:
        tile_c = tuned_t
    else:
        for _, max_c, tile_c in table:
            if max_c is None or c <= max_c:
                break
    return regime, max(1, min(tile_c, c))


# ---------------------------------------------------------------------------
# Per-shape checks
# ---------------------------------------------------------------------------

def check_rsr_shape(cfg_name: str, nb: int, n: int, k: int, *, table, tuned,
                    rows=None, budget: int = hw.VMEM_KERNEL_BUDGET
                    ) -> list[Finding]:
    """All RL1xx findings for one quantized-linear code shape (nb, n)."""
    findings = []
    path = "src/repro/kernels/dispatch.py"
    per = 4  # uint8 codes at the serve default k<=5 pack 4 per u32 word
    for b in (rows if rows is not None else contracts.probe_rows()):
        regime, tiles = _rsr_tiles(b, nb, n, table, tuned)
        if regime is None:
            findings.append(Finding(
                "RL103", path, f"{cfg_name}:rsr:b={b}",
                f"no AUTOTUNE_TABLE regime covers {b} batch rows "
                f"(linear nb={nb} n={n})"))
            continue
        tb, tblk, tn = tiles
        sub = hw.vmem_sublane(_F32)
        bad = []
        if tn % hw.VMEM_LANE:
            bad.append(f"tile_n={tn} % lane {hw.VMEM_LANE}")
        if tn % per:
            bad.append(f"tile_n={tn} % packed-words {per}")
        if tb % sub:
            bad.append(f"tile_b={tb} % sublane {sub}")
        if tblk % sub:
            bad.append(f"tile_blk={tblk} % sublane {sub}")
        if bad:
            findings.append(Finding(
                "RL102", path, f"{cfg_name}:rsr:{regime}:{tb}x{tblk}x{tn}",
                f"misaligned tiles for linear nb={nb} n={n} at b={b}: "
                + "; ".join(bad)))
        ws = rsr_workset_bytes((tb, tblk, tn), k)
        if ws > budget:
            findings.append(Finding(
                "RL101", path, f"{cfg_name}:rsr:{regime}:{tb}x{tblk}x{tn}",
                f"working set {ws / 2**20:.1f} MiB > budget "
                f"{budget / 2**20:.1f} MiB for linear nb={nb} n={n} at "
                f"b={b}"))
    return findings


def check_attn_geometry(cfg, *, table, tuned, chunks=None,
                        block_size: int = contracts.ANALYSIS_KV_BLOCK,
                        budget: int = hw.VMEM_KERNEL_BUDGET
                        ) -> list[Finding]:
    """All RL1xx findings for one config's paged-attention geometry."""
    findings = []
    path = "src/repro/kernels/paged_attention.py"
    try:
        import jax.numpy as jnp
        itemsize = jnp.dtype(cfg.dtype).itemsize
    except TypeError:
        itemsize = 2
    mla = cfg.attention == "mla"
    # lane alignment of the pool trailing dims is a property of the config
    # geometry itself, independent of the query tile
    lanes = ([("kv_lora_rank", cfg.kv_lora_rank),
              ("qk_rope_head_dim", cfg.qk_rope_head_dim)] if mla
             else [("head_dim", cfg.resolved_head_dim)])
    for dim_name, dim in lanes:
        if dim % hw.VMEM_LANE:
            findings.append(Finding(
                "RL102", path, f"{cfg.name}:paged_attn:{dim_name}={dim}",
                f"pool trailing dim {dim_name}={dim} is not a multiple of "
                f"the {hw.VMEM_LANE}-lane tile (Mosaic pads each block's "
                f"last dim; VMEM and DMA are charged for "
                f"{_round_up(dim, hw.VMEM_LANE)})"))
    sub = hw.vmem_sublane(itemsize)
    if block_size % sub:
        findings.append(Finding(
            "RL102", path, f"{cfg.name}:paged_attn:block_size={block_size}",
            f"kv_block_size={block_size} is not a multiple of the "
            f"{sub}-row sublane tile for {cfg.dtype}"))
    for c in (chunks if chunks is not None else contracts.probe_chunks()):
        regime, tc = _attn_tile(c, table, tuned)
        if regime is None:
            findings.append(Finding(
                "RL103", path, f"{cfg.name}:paged_attn:c={c}",
                f"no PAGED_ATTN_TILES regime covers a {c}-token query "
                f"chunk"))
            continue
        if mla:
            ws = mla_workset_bytes(tc, cfg.num_heads, cfg.kv_lora_rank,
                                   cfg.qk_rope_head_dim, block_size,
                                   itemsize)
        else:
            ws = gqa_workset_bytes(tc, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, block_size,
                                   itemsize)
        if ws > budget:
            findings.append(Finding(
                "RL101", path, f"{cfg.name}:paged_attn:{regime}:tc={tc}",
                f"working set {ws / 2**20:.1f} MiB > budget "
                f"{budget / 2**20:.1f} MiB at C={c} (tile_c={tc})"))
    return findings


# ---------------------------------------------------------------------------
# Zoo shape extraction
# ---------------------------------------------------------------------------

def _walk_codes(tree, out):
    if isinstance(tree, dict):
        if "codes" in tree and "n_out" in tree:
            out.add(tuple(tree["codes"].shape[-2:]))
        else:
            for v in tree.values():
                _walk_codes(v, out)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            _walk_codes(v, out)


@functools.lru_cache(maxsize=None)
def _serve_shapes(arch: str) -> frozenset:
    """Distinct (nb, n) code shapes of every quantized serve linear of an
    arch, from the abstract (eval_shape) serve tree — no allocation."""
    import jax
    from repro.config import get_config
    from repro.models import transformer as tfm
    cfg = get_config(arch)
    params = jax.eval_shape(functools.partial(tfm.init_params, cfg),
                            jax.random.PRNGKey(0))
    tree = jax.eval_shape(functools.partial(tfm.serve_params, cfg=cfg),
                          params)
    shapes: set = set()
    _walk_codes(tree, shapes)
    return frozenset(shapes)


def _paged_attention_applies(cfg) -> bool:
    from repro.models.transformer import layer_kinds
    return (not cfg.is_encoder and cfg.attention != "none"
            and any(k == "attn" for k in layer_kinds(cfg)))


def _load_overlay(root: str) -> tuple[dict, dict, list[Finding]]:
    """The autotune_cache.json overlay at ``root`` (validated offline; a
    malformed file is an RL104 finding, not a crash)."""
    from repro.kernels.dispatch import (AutotuneCacheError,
                                        validate_autotune_payload)
    path = os.path.join(root, "autotune_cache.json")
    if not os.path.exists(path):
        return {}, {}, []
    try:
        with open(path) as f:
            payload = json.load(f)
        tuned, attn_tuned = validate_autotune_payload(payload)
    except (json.JSONDecodeError, AutotuneCacheError) as e:
        return {}, {}, [Finding("RL104", "autotune_cache.json",
                                "payload", str(e))]
    return tuned, attn_tuned, []


def check(root: str, archs=None) -> list[Finding]:
    from repro.config import get_config, list_archs
    from repro.kernels.dispatch import AUTOTUNE_TABLE
    from repro.kernels.paged_attention import PAGED_ATTN_TILES
    tuned, attn_tuned, findings = _load_overlay(root)
    seen: set[str] = set()
    for arch in (archs if archs is not None else list_archs()):
        cfg = get_config(arch)
        for nb, n in sorted(_serve_shapes(arch)):
            for f in check_rsr_shape(cfg.name, nb, n, cfg.rsr_k,
                                     table=AUTOTUNE_TABLE, tuned=tuned):
                if f.key not in seen:
                    seen.add(f.key)
                    findings.append(f)
        if _paged_attention_applies(cfg):
            for f in check_attn_geometry(cfg, table=PAGED_ATTN_TILES,
                                         tuned=attn_tuned):
                if f.key not in seen:
                    seen.add(f.key)
                    findings.append(f)
    return findings
