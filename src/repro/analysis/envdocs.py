"""RL4xx — env-var documentation drift checker (pure AST + docstring).

The operator env table lives in the ``repro.serve`` module docstring
(``contracts.ENV_TABLE_FILE``).  Every ``REPRO_*`` variable the code
actually reads (``os.environ.get`` / ``os.getenv`` / ``os.environ[...]``
anywhere under ``contracts.ENV_SCAN_DIRS``, including reads routed
through a module-level name constant like ``_ENV_VAR =
"REPRO_RSR_BACKEND"``) must appear in that table (RL401), and every
table row must correspond to a real read (RL402) — the table is the
serve plane's operator contract, and both directions of drift ship
wrong runbooks.
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis import contracts
from repro.analysis.findings import Finding

__all__ = ["check", "documented_vars", "env_reads"]

_DOC_ROW = re.compile(r"``(%s\w+)``" % re.escape(contracts.ENV_PREFIX))


def documented_vars(source: str) -> set[str]:
    """REPRO_* names in the module docstring's env table."""
    doc = ast.get_docstring(ast.parse(source)) or ""
    return set(_DOC_ROW.findall(doc))


def _str_constants(tree: ast.Module) -> dict[str, str]:
    """module-level NAME = "literal" bindings (``_ENV_VAR`` indirection)."""
    out = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.value.value
    return out


def _env_key(node: ast.AST, consts: dict[str, str]) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    return None


def env_reads(source: str) -> dict[str, int]:
    """{REPRO_* var -> first line read} in one file."""
    tree = ast.parse(source)
    consts = _str_constants(tree)
    reads: dict[str, int] = {}

    def record(key_node, lineno):
        key = _env_key(key_node, consts)
        if key and key.startswith(contracts.ENV_PREFIX):
            reads.setdefault(key, lineno)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            # os.environ.get(K) / os.getenv(K)
            if (isinstance(f, ast.Attribute) and f.attr in ("get", "getenv")
                    and node.args):
                base = f.value
                is_env = (isinstance(base, ast.Attribute)
                          and base.attr == "environ")
                is_os = isinstance(base, ast.Name) and base.id == "os"
                if is_env or (f.attr == "getenv" and is_os):
                    record(node.args[0], node.lineno)
        elif isinstance(node, ast.Subscript):
            # os.environ[K]
            v = node.value
            if isinstance(v, ast.Attribute) and v.attr == "environ":
                record(node.slice, node.lineno)
    return reads


def check(root: str) -> list[Finding]:
    table_path = os.path.join(root, contracts.ENV_TABLE_FILE)
    with open(table_path) as f:
        documented = documented_vars(f.read())
    read_at: dict[str, tuple[str, int]] = {}
    for rel in contracts.ENV_SCAN_DIRS:
        base = os.path.join(root, rel)
        for dirpath, _, names in os.walk(base):
            for n in sorted(names):
                if not n.endswith(".py"):
                    continue
                path = os.path.join(dirpath, n)
                rel_path = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path) as f:
                    for var, line in env_reads(f.read()).items():
                        read_at.setdefault(var, (rel_path, line))
    findings = []
    for var in sorted(set(read_at) - documented):
        rel_path, line = read_at[var]
        findings.append(Finding(
            "RL401", rel_path, var,
            f"{var} is read here but missing from the operator env table "
            f"in {contracts.ENV_TABLE_FILE}",
            line=line))
    for var in sorted(documented - set(read_at)):
        findings.append(Finding(
            "RL402", contracts.ENV_TABLE_FILE, var,
            f"{var} is documented in the operator env table but nothing "
            f"under {'/'.join(contracts.ENV_SCAN_DIRS)} reads it"))
    return findings
