"""``python -m repro.analysis`` / ``repro-lint`` — the reprolint CLI.

Exit status: 0 unless ``--fail-on-findings`` is given and at least one
finding is NOT in the suppression baseline.  See the package docstring
for the finding codes and the baseline format.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import CHECKERS, run_checks
from repro.analysis.findings import (default_baseline_path, format_report,
                                     load_baseline, save_baseline,
                                     split_findings)


def _repo_root() -> str:
    """Default tree to lint: the repo containing this package (src/../..)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="static invariant checker for the RSR serve stack")
    ap.add_argument("--root", default=_repo_root(),
                    help="tree to lint (default: this checkout)")
    ap.add_argument("--baseline", default=None,
                    help="suppression baseline path (default: "
                         "$REPRO_ANALYSIS_BASELINE or "
                         "<root>/reprolint_baseline.json)")
    ap.add_argument("--checks", default=None, metavar="A,B",
                    help=f"comma-separated subset of {sorted(CHECKERS)}")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 if any non-baselined finding fires")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "(existing justifications kept; new entries get a "
                         "TODO marker the loader rejects)")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root)
    names = [n.strip() for n in args.checks.split(",")] if args.checks else None
    baseline_path = args.baseline or default_baseline_path(root)

    findings = run_checks(root, names)

    if args.write_baseline:
        previous = (load_baseline(baseline_path)
                    if os.path.exists(baseline_path) else {})
        save_baseline(baseline_path, findings, previous)
        print(f"reprolint: wrote {len(findings)} suppression(s) to "
              f"{baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, suppressed, stale = split_findings(findings, baseline)
    print(format_report(new, suppressed, stale))
    return 1 if (args.fail_on_findings and new) else 0


if __name__ == "__main__":
    sys.exit(main())
