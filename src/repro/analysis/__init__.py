"""reprolint — static invariant checker for the RSR serve stack.

The paper's win rests on contracts the runtime only checks after the fact,
if ever: packed code words must stay exact integer streams end-to-end (one
silent float cast destroys the ~1.6 bits/weight base-3 encoding), Pallas
tile choices must fit VMEM and TPU lane/sublane alignment for every config
in the zoo, and scheduler state (block tables, refcounts, the position
mirror) must stay host-side ``np``/int — the PR-7 auditor catches the last
family only per tick, at runtime, after the corruption happened.  This
package proves those contracts over the whole tree **before any TPU
compile**:

    python -m repro.analysis                  # report all findings
    python -m repro.analysis --fail-on-findings   # CI gate (exit 1 on new)
    repro-lint --checks tiles,envdocs         # console entry point

Checkers and finding codes
--------------------------
``tiles`` (:mod:`repro.analysis.tiles`) — evaluates ``AUTOTUNE_TABLE`` /
``TUNED_TILES`` (kernels/dispatch.py), ``PAGED_ATTN_TILES`` /
``TUNED_ATTN_TILES`` (kernels/paged_attention.py) and the
autotune_cache.json overlay against every config in ``repro.configs``
under the per-hardware VMEM model in ``roofline/hw.py``:

* **RL101** vmem-overflow — a kernel launch's working set (double-buffered
  operand tiles + scratch + resident intermediates) exceeds
  ``hw.VMEM_KERNEL_BUDGET``.
* **RL102** tile-misaligned — a post-clamp tile violates TPU tiling (last
  dim % ``hw.VMEM_LANE``, penultimate % sublane for the dtype, packed-word
  divisibility) for some zoo shape.
* **RL103** shape-uncovered — a row-count / chunk size the serve engine
  can produce has no covering regime entry in the static tables.
* **RL104** invalid-overlay-entry — an autotune_cache.json entry fails
  validation (``dispatch.validate_autotune_payload``; the loader raises
  ``AutotuneCacheError`` at runtime, the linter reports it statically).

``boundaries`` (:mod:`repro.analysis.boundaries`) — AST pass over the
host/device split:

* **RL201** traced-into-host-state — a ``serve/`` assignment stores a
  ``jnp``/traced value into declared host state (BlockPool internals, the
  host block tables, the scheduler position mirror) without a
  ``jax.device_get``/``np.asarray``/``int`` materialization boundary.
* **RL202** jnp-math-on-host-state — ``jnp`` compute (not a mere
  host→device conversion) applied directly to declared host state: a
  silent device round-trip on the scheduler tick path.
* **RL203** host-op-in-traced-fn — ``np.`` calls, prints, file/env/clock
  access, ``jax.device_get`` or ``.block_until_ready()`` inside a jitted
  function, a Pallas kernel body, or anything statically reachable from
  the declared trace roots (``contracts.TRACE_ROOTS``) in ``kernels/`` and
  ``models/``.

``dtypeflow`` (:mod:`repro.analysis.dtypeflow`) — taint pass over the
packed-code path (``core/preprocess.pack_code_words`` →
``kernels/dispatch`` → ``rsr_onehot``):

* **RL301** code-word-float-cast — a value carrying code words (taint
  seeded from the ``codes``/``packed``/``words`` lexicon, dict keys, and
  the pack/unpack helpers; comparisons break taint, so one-hot builds are
  clean) is cast or coerced to a float dtype.
* **RL302** scale-dtype-drift — a dequant ``scale``/``gamma`` value is
  cast to a non-f32 float (absmean γ must stay exact f32 into the kernel
  epilogue).

``envdocs`` (:mod:`repro.analysis.envdocs`) — the ``REPRO_*`` registry:

* **RL401** env-read-undocumented — an env var read anywhere in ``src/``
  (including reads through module-level name constants) missing from the
  ``serve/__init__.py`` env table.
* **RL402** env-doc-stale — a table row documenting a variable nothing
  reads.

``metricsdocs`` (:mod:`repro.analysis.metricsdocs`) — the telemetry
metric catalog (``serve/__init__.py`` Observability section):

* **RL501** metric-undocumented — a ``serve_*``/``rsr_*`` family name
  handed to a telemetry constructor (``counter``/``gauge``/
  ``histogram``/``stats_counters`` or the class forms) anywhere in
  ``src/`` that is missing from the catalog.
* **RL502** metric-doc-stale — a catalogued name nothing emits.

Suppression baseline
--------------------
``reprolint_baseline.json`` at the repo root is the committed list of
*accepted* findings — each entry is ``{"key", "justification"}`` where
``key`` is the finding's stable fingerprint (``CODE:path:symbol``, no line
numbers, printed with every finding) and ``justification`` is a mandatory
one-liner saying why the finding is intentional.  ``--write-baseline``
regenerates the file from the current findings (justifications for
already-known keys are preserved).  The CI gate fails on any finding not
in the baseline AND warns on stale baseline entries, so the file can only
shrink or be consciously grown.

Extending with a new checker
----------------------------
1. Add ``repro/analysis/<name>.py`` exposing
   ``check(root: str) -> list[Finding]`` (use :class:`findings.Finding`;
   pick an unused RLxxx range and keep ``symbol`` stable across line
   moves — it is the baseline fingerprint).
2. Register it in ``CHECKERS`` below and document its codes in this
   docstring.
3. Add a seeded-violation fixture to ``tests/test_analysis.py`` proving
   the checker fires, and a clean-tree assertion proving it stays quiet.
Shared contract declarations (host-state attribute names, trace roots,
the code-word lexicon, the canonical serve geometry the tile checker
probes) live in :mod:`repro.analysis.contracts`.
"""
from __future__ import annotations

from repro.analysis.findings import Finding, load_baseline, split_findings

__all__ = ["Finding", "CHECKERS", "run_checks", "load_baseline",
           "split_findings"]


def _check_tiles(root: str):
    from repro.analysis.tiles import check
    return check(root)


def _check_boundaries(root: str):
    from repro.analysis.boundaries import check
    return check(root)


def _check_dtypeflow(root: str):
    from repro.analysis.dtypeflow import check
    return check(root)


def _check_envdocs(root: str):
    from repro.analysis.envdocs import check
    return check(root)


def _check_metricsdocs(root: str):
    from repro.analysis.metricsdocs import check
    return check(root)


#: name -> callable(root) -> list[Finding]; ordered as reported.
CHECKERS = {
    "tiles": _check_tiles,
    "boundaries": _check_boundaries,
    "dtypeflow": _check_dtypeflow,
    "envdocs": _check_envdocs,
    "metricsdocs": _check_metricsdocs,
}


def run_checks(root: str, names=None) -> list:
    """Run the named checkers (default: all) over the tree at ``root``."""
    out = []
    for name in (names or CHECKERS):
        if name not in CHECKERS:
            raise KeyError(f"unknown checker {name!r}; have "
                           f"{sorted(CHECKERS)}")
        out.extend(CHECKERS[name](root))
    return out
