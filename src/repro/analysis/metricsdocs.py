"""RL5xx — metric-name documentation drift checker (pure AST + docstring).

The metric name catalog lives in the ``repro.serve`` module docstring's
Observability section (``contracts.METRIC_CATALOG_FILE``), the same
doc-as-contract pattern as the RL4xx env table.  Every metric family
name the code emits — a string literal (or module-level name constant)
handed as the first argument to one of the telemetry constructors
(``contracts.METRIC_CALLS``: ``counter`` / ``gauge`` / ``histogram`` /
``stats_counters`` and the class forms) and starting with a
``contracts.METRIC_PREFIXES`` prefix — must appear in that catalog
(RL501), and every catalogued name must correspond to a real emission
(RL502).  A dashboard built against the catalog must never find a
metric missing, and the catalog must never advertise one that nothing
produces.
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis import contracts
from repro.analysis.findings import Finding

__all__ = ["check", "documented_metrics", "metric_emits"]

_DOC_ROW = re.compile(
    r"``((?:%s)\w+)``" % "|".join(
        re.escape(p) for p in contracts.METRIC_PREFIXES))


def documented_metrics(source: str) -> set[str]:
    """serve_*/rsr_* names in the module docstring's metric catalog."""
    doc = ast.get_docstring(ast.parse(source)) or ""
    return set(_DOC_ROW.findall(doc))


def _str_constants(tree: ast.Module) -> dict[str, str]:
    """module-level NAME = "literal" bindings (name-constant indirection,
    same resolution the env checker does)."""
    out = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.value.value
    return out


def _call_name(func: ast.AST) -> str | None:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def metric_emits(source: str) -> dict[str, int]:
    """{metric family name -> first emission line} in one file."""
    tree = ast.parse(source)
    consts = _str_constants(tree)
    emits: dict[str, int] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        if _call_name(node.func) not in contracts.METRIC_CALLS:
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            name = arg.value
        elif isinstance(arg, ast.Name):
            name = consts.get(arg.id)
        else:
            name = None
        if name and name.startswith(contracts.METRIC_PREFIXES):
            emits.setdefault(name, node.lineno)
    return emits


def check(root: str) -> list[Finding]:
    catalog_path = os.path.join(root, contracts.METRIC_CATALOG_FILE)
    with open(catalog_path) as f:
        documented = documented_metrics(f.read())
    emitted_at: dict[str, tuple[str, int]] = {}
    for rel in contracts.METRIC_SCAN_DIRS:
        base = os.path.join(root, rel)
        for dirpath, _, names in os.walk(base):
            for n in sorted(names):
                if not n.endswith(".py"):
                    continue
                path = os.path.join(dirpath, n)
                rel_path = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path) as f:
                    for name, line in metric_emits(f.read()).items():
                        emitted_at.setdefault(name, (rel_path, line))
    findings = []
    for name in sorted(set(emitted_at) - documented):
        rel_path, line = emitted_at[name]
        findings.append(Finding(
            "RL501", rel_path, name,
            f"metric {name} is emitted here but missing from the metric "
            f"catalog in {contracts.METRIC_CATALOG_FILE}",
            line=line))
    for name in sorted(documented - set(emitted_at)):
        findings.append(Finding(
            "RL502", contracts.METRIC_CATALOG_FILE, name,
            f"metric {name} is catalogued but nothing under "
            f"{'/'.join(contracts.METRIC_SCAN_DIRS)} emits it"))
    return findings
