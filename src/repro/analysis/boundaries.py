"""RL2xx — host/device boundary checker (pure AST, nothing imported).

Two directions:

* serve plane (``contracts.SERVE_DIRS``): host scheduler/allocator state
  (``contracts.HOST_STATE_ATTRS``) must stay plain Python/NumPy.  RL201
  fires when a traced (``jnp.``) value is stored into host state without
  crossing the boundary through a wrapper (``jax.device_get`` /
  ``np.asarray`` / ``int`` / ...); RL202 fires when a ``jnp`` compute op
  (anything outside ``contracts.JNP_CONVERTERS``) consumes host state
  directly — each device round-trip there is a hidden sync in the
  scheduler hot path.

* traced plane (``contracts.TRACED_DIRS``): functions reachable from a
  trace root (``@jax.jit``, a Pallas kernel body, or a declared
  ``contracts.TRACE_ROOTS`` entry) must not perform host work.  RL203
  fires on ``np.`` calls, ``os.environ`` reads, ``open``/``print`` —
  side effects that run once at trace time and are silently frozen into
  the compiled artifact.
"""
from __future__ import annotations

import ast
import os

from repro.analysis import contracts
from repro.analysis.findings import Finding

__all__ = ["check", "check_serve_source", "check_traced_tree"]


def _py_files(root: str, rel_dirs) -> list[str]:
    out = []
    for rel in rel_dirs:
        base = os.path.join(root, rel)
        for dirpath, _, names in os.walk(base):
            for n in sorted(names):
                if n.endswith(".py"):
                    out.append(os.path.join(dirpath, n))
    return sorted(out)


def _rel(root: str, path: str) -> str:
    return os.path.relpath(path, root).replace(os.sep, "/")


# ---------------------------------------------------------------------------
# serve plane: RL201 / RL202
# ---------------------------------------------------------------------------

def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _is_jnp_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == "jnp")


def _host_attr(node: ast.AST) -> str | None:
    """Name of the host-state attribute this expression roots in, if any
    (``self._tables``, ``self._tables[slot]``, ``pool._free`` ...)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and node.attr in contracts.HOST_STATE_ATTRS):
        return node.attr
    return None


def _unshielded_jnp(expr: ast.AST) -> ast.Call | None:
    """First ``jnp.`` call in ``expr`` not nested under a boundary wrapper."""

    def visit(node, shielded):
        if isinstance(node, ast.Call):
            if _is_jnp_call(node) and not shielded:
                return node
            child_shield = (shielded
                            or _call_name(node) in contracts.BOUNDARY_WRAPPERS)
            for c in ast.iter_child_nodes(node):
                hit = visit(c, child_shield)
                if hit is not None:
                    return hit
            return None
        for c in ast.iter_child_nodes(node):
            hit = visit(c, shielded)
            if hit is not None:
                return hit
        return None

    return visit(expr, False)


def check_serve_source(rel_path: str, source: str) -> list[Finding]:
    findings = []
    tree = ast.parse(source)
    for node in ast.walk(tree):
        # RL201: traced value assigned into host state
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            value = node.value
            if value is None:
                continue
            attrs = [a for a in (_host_attr(t) for t in targets) if a]
            if attrs:
                hit = _unshielded_jnp(value)
                if hit is not None:
                    findings.append(Finding(
                        "RL201", rel_path, attrs[0],
                        f"traced value (jnp.{hit.func.attr}) stored into "
                        f"host state .{attrs[0]} — host scheduler state "
                        f"must stay NumPy/Python (wrap with jax.device_get "
                        f"/ np.asarray to cross the boundary)",
                        line=node.lineno))
        # RL202: jnp compute op consuming host state
        if (_is_jnp_call(node)
                and node.func.attr not in contracts.JNP_CONVERTERS):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    a = _host_attr(sub)
                    if a:
                        findings.append(Finding(
                            "RL202", rel_path, f"{a}:jnp.{node.func.attr}",
                            f"jnp.{node.func.attr} applied to host state "
                            f".{a} — implicit host->device transfer in the "
                            f"scheduler path; compute on host (np) or "
                            f"convert explicitly first",
                            line=node.lineno))
                        break
                else:
                    continue
                break
    return findings


# ---------------------------------------------------------------------------
# traced plane: RL203
# ---------------------------------------------------------------------------

def _is_jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        names = {n.attr if isinstance(n, ast.Attribute) else getattr(n, "id", "")
                 for n in ast.walk(dec) if isinstance(n, (ast.Attribute, ast.Name))}
        if "jit" in names:
            return True
    return False


def _pallas_bodies(tree: ast.Module) -> set[str]:
    """Function names handed to ``pl.pallas_call`` — directly or through a
    ``functools.partial(fn, ...)`` bound to a local name first."""
    partial_alias: dict[str, str] = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)
                and _call_name(node.value) == "partial" and node.value.args
                and isinstance(node.value.args[0], ast.Name)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    partial_alias[t.id] = node.value.args[0].id
    bodies: set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and _call_name(node) == "pallas_call"
                and node.args and isinstance(node.args[0], ast.Name)):
            name = node.args[0].id
            bodies.add(partial_alias.get(name, name))
    return bodies


def _called_names(fn: ast.FunctionDef) -> set[str]:
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            n = _call_name(node)
            if n:
                out.add(n)
    return out


def _host_ops_in(fn: ast.FunctionDef, rel_path: str, via: str
                 ) -> list[Finding]:
    findings = []
    for node in ast.walk(fn):
        what = None
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                    and f.value.id == "np"):
                what = f"np.{f.attr} call"
            elif isinstance(f, ast.Name) and f.id in ("open", "print", "input"):
                what = f"{f.id}() call"
            elif isinstance(f, ast.Attribute) and f.attr in (
                    "device_get", "block_until_ready"):
                what = f"{f.attr} sync"
            elif (isinstance(f, ast.Attribute)
                  and isinstance(f.value, ast.Name) and f.value.id == "time"
                  and f.attr in ("time", "perf_counter", "monotonic")):
                what = f"time.{f.attr} read"
        elif (isinstance(node, ast.Attribute) and node.attr == "environ"
              and isinstance(node.value, ast.Name) and node.value.id == "os"):
            what = "os.environ read"
        elif isinstance(node, ast.Call) and _call_name(node) == "getenv":
            what = "os.getenv read"
        if what:
            findings.append(Finding(
                "RL203", rel_path, f"{fn.name}:{what.split()[0]}",
                f"{what} inside traced function {fn.name} (reached via "
                f"{via}) — runs once at trace time and is frozen into the "
                f"compiled artifact",
                line=node.lineno))
    return findings


def check_traced_tree(files: dict[str, str]) -> list[Finding]:
    """RL203 over {rel_path: source}: seed trace roots, BFS the intra-set
    call graph by simple name, flag host ops in every reachable function."""
    fns: dict[str, list[tuple[str, ast.FunctionDef]]] = {}
    seeds: dict[str, str] = {}           # fn name -> why it is a root
    calls: dict[str, set[str]] = {}
    for rel_path, source in files.items():
        tree = ast.parse(source)
        pallas = _pallas_bodies(tree)
        declared = contracts.TRACE_ROOTS.get(rel_path, frozenset())
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            fns.setdefault(node.name, []).append((rel_path, node))
            calls[node.name] = calls.get(node.name, set()) | _called_names(node)
            if node.name in pallas:
                seeds.setdefault(node.name, "pallas_call body")
            elif _is_jit_decorated(node):
                seeds.setdefault(node.name, "@jit")
            elif node.name in declared:
                seeds.setdefault(node.name, "declared trace root")
    # deterministic breadth-first closure (stable shortest "via" chains)
    via: dict[str, str] = dict(sorted(seeds.items()))
    frontier = sorted(seeds)
    while frontier:
        name = frontier.pop(0)
        for callee in sorted(calls.get(name, ())):
            if callee in fns and callee not in via:
                via[callee] = f"{via[name]} -> {name}"
                frontier.append(callee)
    findings = []
    seen = set()
    for name, why in sorted(via.items()):
        for rel_path, fn in fns[name]:
            for f in _host_ops_in(fn, rel_path, why):
                if f.key not in seen:
                    seen.add(f.key)
                    findings.append(f)
    return findings


def check(root: str) -> list[Finding]:
    findings = []
    for path in _py_files(root, contracts.SERVE_DIRS):
        with open(path) as f:
            findings.extend(check_serve_source(_rel(root, path), f.read()))
    traced = {}
    for path in _py_files(root, contracts.TRACED_DIRS):
        with open(path) as f:
            traced[_rel(root, path)] = f.read()
    findings.extend(check_traced_tree(traced))
    return findings
