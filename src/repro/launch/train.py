"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 50 --batch 8 --seq 64

On the production pod this runs the same code under the 16×16 (or 2×16×16)
mesh with FSDP×TP sharding; on this container it runs the reduced config on
the local device.  Fault tolerance (checkpoint/restart) is always on.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.config import TrainConfig, get_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as tfm
from repro.parallel import sharding as shd
from repro.train import data as data_lib
from repro.train.fault import FaultManager
from repro.train.loop import train_state_init, train_step
from repro.train.optimizer import OptState


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "lion"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                      warmup_steps=max(5, args.steps // 20),
                      microbatches=args.microbatches,
                      optimizer=args.optimizer,
                      grad_compression=args.grad_compression,
                      checkpoint_dir=args.ckpt)

    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(1, 1))
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} steps={args.steps}")

    state = train_state_init(cfg, tcfg, jax.random.PRNGKey(tcfg.seed))
    p_specs = shd.param_pspecs(state["params"], mesh)
    sspec = {"params": p_specs,
             "opt": OptState(step=jax.sharding.PartitionSpec(),
                             mu=p_specs, nu=p_specs)}
    fm = FaultManager(args.ckpt, checkpoint_every=tcfg.checkpoint_every)
    start = 0
    if args.resume:
        start, restored = fm.restore_latest(
            state, shardings_tree=shd.shardings(sspec, mesh))
        if restored is not None:
            state = restored
            print(f"resumed from step {start}")

    with mesh:
        stepper = jax.jit(functools.partial(train_step, cfg=cfg, tcfg=tcfg),
                          in_shardings=(shd.shardings(sspec, mesh), None),
                          out_shardings=(shd.shardings(sspec, mesh), None),
                          donate_argnums=(0,))

        def batch_fn(step):
            return jax.tree.map(jnp.asarray, data_lib.synthetic_batch(
                cfg, args.batch, args.seq, step))

        t0 = time.time()

        def on_metrics(step, m):
            if step % 10 == 0:
                print(f"step {step:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"({(time.time()-t0)/max(step-start,1):.2f}s/step)",
                      flush=True)

        state = fm.run(state, stepper, batch_fn, args.steps,
                       state_like=state, on_metrics=on_metrics)
    print("training complete; final checkpoint:",
          fm.restore_latest(state)[0])


if __name__ == "__main__":
    main()
