"""Production mesh construction (TPU v5e pods; 256 chips/pod).

A function, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod mesh ("data","model") or 2×16×16 multi-pod
    ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh((data, model), ("data", "model"))
