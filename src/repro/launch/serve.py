"""Serving driver CLI: initialize (or load) ternary weights, preprocess to
RSR indices, serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch falcon3-3b-1.58bit \
        --reduced --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve.engine import BatchScheduler, Engine, Request
from repro.train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="restore trained params from this checkpoint dir")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--no-rsr", action="store_true",
                    help="serve dense-dequant instead of RSR indices")
    ap.add_argument("--kv-block", type=int, default=0,
                    help="KV block size; > 0 serves from the block-paged "
                         "cache with shared-prefix reuse")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged pool size in blocks (0 = dense-equivalent)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.no_rsr:
        import dataclasses
        cfg = dataclasses.replace(cfg, rsr_serve=False)

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        step = ckpt.latest_step(args.ckpt)
        state_like = {"params": params}
        params = ckpt.restore(args.ckpt, step, state_like)["params"]
        print(f"restored params from {args.ckpt} step {step}")

    t0 = time.time()
    serve_tree = tfm.serve_params(params, cfg)
    print(f"offline preprocessing: {time.time()-t0:.2f}s "
          f"(mode={'RSR' if cfg.rsr_serve else 'dense-dequant'})")

    engine = Engine(cfg, serve_tree,
                    ServeConfig(max_seq_len=args.max_seq,
                                batch_size=args.batch,
                                temperature=args.temperature,
                                kv_block_size=args.kv_block,
                                kv_num_blocks=args.kv_blocks))
    sched = BatchScheduler(engine)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        sched.submit(Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
            max_new=args.max_new))
    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s")
    if engine.paged:
        st = engine.pool.stats
        hit = st["hit_tokens"] / max(1, st["lookup_tokens"])
        print(f"paged kv: block={engine.layout.block_size} "
              f"pool={engine.layout.num_blocks} "
              f"prefix_hit_rate={hit:.2f} cow={st['cow_copies']}")
    for r in done:
        print(f"  req {r.rid}: {r.generated[:8]}...")


if __name__ == "__main__":
    main()
