import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch × shape × mesh) cell.

For each cell this builds abstract inputs (ShapeDtypeStruct — zero
allocation), the sharded step function (train_step / forward-prefill /
decode_step), runs ``.lower().compile()`` against the production mesh, and
records memory_analysis / cost_analysis / per-kind collective bytes +
derived roofline terms into a JSON file under experiments/dryrun/.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

The XLA_FLAGS line above MUST precede any jax import (device count locks at
first init); smoke tests / benches import repro modules directly and see 1
device.
"""
import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (ModelConfig, SHAPES, ShapeConfig, TrainConfig,
                          get_config, list_archs, shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.parallel import sharding as shd
from repro.roofline import analysis as roof
from repro.roofline import flops as fl
from repro.train import loop as train_loop

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_stub":
            batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "vision_stub":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), dt)
        return batch
    # decode: one new token against a seq_len KV cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        functools.partial(train_loop.train_state_init, cfg, tcfg),
        jax.random.PRNGKey(0))


def abstract_serve(cfg: ModelConfig):
    params = jax.eval_shape(functools.partial(tfm.init_params, cfg),
                            jax.random.PRNGKey(0))
    return jax.eval_shape(functools.partial(tfm.serve_params, cfg=cfg),
                          params)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               cfg_override=None):
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    tcfg = TrainConfig(remat="block")
    chips = int(np.prod(list(mesh.shape.values())))

    with mesh:
        if shape.kind == "train":
            state_abs = abstract_state(cfg, tcfg)
            batch_abs = input_specs(cfg, shape)
            state_specs = {
                "params": shd.param_pspecs(state_abs["params"], mesh),
            }
            from repro.train.optimizer import OptState
            p_specs = state_specs["params"]
            state_specs["opt"] = OptState(
                step=jax.sharding.PartitionSpec(),
                mu=p_specs, nu=p_specs)
            batch_specs = shd.batch_pspecs(batch_abs, mesh)
            step = functools.partial(train_loop.train_step, cfg=cfg,
                                     tcfg=tcfg)
            lowered = jax.jit(
                step,
                in_shardings=(shd.shardings(state_specs, mesh),
                              shd.shardings(batch_specs, mesh)),
                out_shardings=(shd.shardings(state_specs, mesh), None),
                donate_argnums=(0,),
            ).lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            sp_abs = abstract_serve(cfg)
            batch_abs = input_specs(cfg, shape)
            sp_specs = shd.param_pspecs(sp_abs, mesh, serve=True)
            batch_specs = shd.batch_pspecs(batch_abs, mesh)

            def prefill(params, batch):
                logits, _ = tfm.forward(params, batch, cfg, quantize=False)
                return logits

            lowered = jax.jit(
                prefill,
                in_shardings=(shd.shardings(sp_specs, mesh),
                              shd.shardings(batch_specs, mesh)),
            ).lower(sp_abs, batch_abs)
        else:                                       # decode
            sp_abs = abstract_serve(cfg)
            cache_abs = tfm.init_cache(cfg, shape.global_batch,
                                       shape.seq_len, abstract=True)
            tok_abs = input_specs(cfg, shape)["tokens"]
            sp_specs = shd.param_pspecs(
                sp_abs, mesh, serve=True,
                replicate_small=shape.global_batch >= 16)
            cache_specs = shd.cache_pspecs(cache_abs, mesh)
            tok_spec = shd.batch_pspecs({"t": tok_abs}, mesh)["t"]

            def serve_step(params, cache, tokens):
                return tfm.decode_step(params, cache, tokens, cfg)

            lowered = jax.jit(
                serve_step,
                in_shardings=(shd.shardings(sp_specs, mesh),
                              shd.shardings(cache_specs, mesh),
                              shd.shardings({"t": tok_spec}, mesh)["t"]),
                donate_argnums=(1,),
            ).lower(sp_abs, cache_abs, tok_abs)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                              getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
    }
    counts = fl.param_counts(
        jax.eval_shape(functools.partial(tfm.init_params, cfg),
                       jax.random.PRNGKey(0)), cfg)
    mflops = fl.model_flops(cfg, shape, counts)
    extra = 0.0
    if shape.kind == "decode" and cfg.rsr_serve and cfg.quant != "none":
        # scatter adds are invisible to XLA cost analysis — add per-chip
        extra = fl.rsr_scatter_flops(abstract_serve(cfg), cfg,
                                     shape.global_batch) / chips
    # analytic per-chip byte floors (CPU-backend HLO inflates bytes ~2-3x by
    # f32-converting every bf16 dot operand — native on TPU; see EXPERIMENTS)
    def tree_bytes(t):
        return float(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                         for l in jax.tree.leaves(t)))
    tp = mesh.shape.get("model", 1)
    analytic = {}
    if shape.kind == "train":
        analytic["param_bytes_per_chip"] = tree_bytes(
            state_abs["params"]) / chips
    else:
        analytic["param_bytes_per_chip"] = tree_bytes(sp_abs) / tp
    if shape.kind == "decode":
        analytic["cache_bytes_per_chip"] = tree_bytes(cache_abs) / chips
        analytic["min_memory_s"] = (
            analytic["param_bytes_per_chip"] +
            analytic["cache_bytes_per_chip"]) / 819e9
    hlo = compiled.as_text()
    # scan-aware HLO cost model (XLA cost_analysis counts while bodies once —
    # ~num_layers undercount on scanned stacks; see roofline/hlo_cost.py)
    from repro.roofline.hlo_cost import analyze_hlo
    hc = analyze_hlo(hlo)
    rl = roof.Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                       chips=chips,
                       hlo_flops=hc["flops"] + extra,
                       hlo_bytes=hc["bytes"],
                       coll_bytes=hc["collectives"]["total"],
                       model_flops=mflops / chips).finalize()
    raw = roof.analyze(compiled, arch=arch, shape=shape_name,
                       mesh_name=mesh_name, chips=chips, model_flops=mflops,
                       hlo_text=hlo, extra_flops=extra)
    coll = roof.collective_bytes(hlo)
    return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok", "chips": chips, "compile_s": compile_s,
            "analytic": analytic,
            "memory": mem_info,
            "bytes_per_device": (mem_info["argument_bytes"] +
                                 mem_info["temp_bytes"] +
                                 mem_info["output_bytes"]) / chips,
            "params_total": counts["total"],
            "params_active": counts["active"],
            "scan_loops": hc["loops"],
            "collectives": {k: v for k, v in hc["collectives"].items()},
            "collective_counts": coll["counts"],
            "roofline": rl.to_dict(),
            "roofline_raw_costanalysis": raw.to_dict()}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    name = f"{arch}__{shape_name}__{mesh_kind}"
    path = os.path.join(out_dir, name + ".json")
    try:
        rec = lower_cell(arch, shape_name, mesh,
                         "2x16x16" if multi else "16x16")
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
                 f"compile={rec['compile_s']:.0f}s "
                 f"bpd={rec['bytes_per_device']/2**30:.2f}GiB")
    elif status == "error":
        extra = " " + rec["error"][:160]
    print(f"[dryrun] {name}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])
    if args.all:
        cells = [(a, s) for a in list_archs()[:10] for s in SHAPES]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        for mk in meshes:
            path = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
            if args.skip_existing and os.path.exists(path):
                rec = json.load(open(path))
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[dryrun] {arch}__{shape}__{mk}: cached "
                          f"({rec['status']})", flush=True)
                    continue
            run_cell(arch, shape, mk, args.out)


if __name__ == "__main__":
    main()
