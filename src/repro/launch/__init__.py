"""Subsystem package."""
