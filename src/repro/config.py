"""Configuration system: model / train / serve / mesh configs + arch registry.

Every assigned architecture lives in ``repro/configs/<id>.py`` exposing a
``CONFIG: ModelConfig``.  ``get_config(name)`` resolves ids (dashes allowed).
``ModelConfig.reduced()`` yields the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

__all__ = ["ModelConfig", "TrainConfig", "ServeConfig", "ShapeConfig",
           "get_config", "list_archs", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000

    # --- attention ---
    attention: str = "gqa"            # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0                   # >0: sliding-window (local) attention
    causal: bool = True
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- FFN ---
    act: str = "silu"                 # silu | gelu
    glu: bool = True                  # gated (SwiGLU / GeGLU) vs plain MLP

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden width
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    expand: int = 2
    conv_width: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # --- hybrid (recurrentgemma/griffin) ---
    block_pattern: Tuple[str, ...] = ("attn",)   # per-superblock layer kinds
    d_rnn: int = 0                    # RG-LRU width (0 -> d_model)

    # --- vlm ---
    cross_attn_every: int = 0         # 1 cross-attn layer per N self-attn
    num_image_tokens: int = 0

    # --- encoder / modality frontend ---
    is_encoder: bool = False
    frontend: str = "none"            # none | audio_stub | vision_stub

    # --- quantization / the paper's technique ---
    quant: str = "ternary"            # none | ternary (QAT train, RSR serve)
    rsr_k: int = 5                    # ternary-direct block width at serve
    rsr_serve: bool = True            # serve linears via RSR indices
    rsr_backend: str = "auto"         # auto | pallas | pallas_interpret |
                                      # scatter (kernels.dispatch resolution)
    quant_head: bool = False          # keep embed/lm_head full precision

    # --- misc ---
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ----------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:         # mamba2 inner width
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True iff attention cost does not grow quadratically with context."""
        has_full_attn = self.attention != "none" and self.window == 0
        return not has_full_attn

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        few = max(1, len(self.block_pattern))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=max(2 * few, self.first_dense_layers + few),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads)),
            head_dim=16 if self.head_dim else 0,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            q_lora_rank=0,
            qk_rope_head_dim=8 if self.attention == "mla" else 64,
            qk_nope_head_dim=16 if self.attention == "mla" else 128,
            v_head_dim=16 if self.attention == "mla" else 128,
            num_experts=min(8, self.num_experts),
            num_experts_per_tok=min(2, self.num_experts_per_tok),
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            d_rnn=64 if self.d_rnn else 0,
            window=min(self.window, 16) if self.window else 0,
            num_image_tokens=16 if self.num_image_tokens else 0,
            cross_attn_every=self.cross_attn_every,
            first_dense_layers=min(1, self.first_dense_layers),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    optimizer: str = "adamw"          # adamw | lion
    zero1: bool = True                # shard optimizer state over data axis
    remat: str = "block"              # none | block | full
    microbatches: int = 1             # gradient accumulation
    grad_compression: str = "none"    # none | int8_ef
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq_len: int = 4096
    batch_size: int = 8
    rsr_impl: str = "onehot"          # segments | scatter | onehot
    temperature: float = 0.0          # 0 -> greedy
    prefill_chunk: int = 32           # tokens per chunked-prefill step
                                      # (B·chunk rows per quantized linear)
    # --- paged KV (0 -> dense per-slot rows) ---
    kv_block_size: int = 0            # tokens per KV block; >0 enables the
                                      # block-paged cache with shared-prefix
                                      # reuse (repro.serve.paging)
    kv_num_blocks: int = 0            # global pool size; 0 -> auto (the
                                      # dense-equivalent batch * blocks/slot)
    paged_attn: str = "auto"          # auto | kernel | gather — paged
                                      # scoring backend (in-place Pallas
                                      # kernel vs dense-gather reference;
                                      # $REPRO_PAGED_ATTN outranks this,
                                      # kernels.paged_attention resolution)
    # --- request plane (repro.serve.frontend; priority scheduler only —
    # the plain FIFO BatchScheduler ignores these) ---
    overcommit: float = 1.0           # admission budget multiplier: the sum
                                      # of running requests' WORST-CASE block
                                      # demands may reach overcommit *
                                      # kv_num_blocks (>1 admits more traffic
                                      # than the pool can hold at once; mid-
                                      # decode exhaustion is resolved by
                                      # victim preemption)
    max_preemptions: int = 3          # K: after K evictions a request is
                                      # PINNED — never picked as a victim
                                      # again and boosted past every lane —
                                      # so repeated preemption cannot
                                      # live-lock it
    lane_aging_s: float = 2.0         # queue wait that promotes a request
                                      # one priority lane (starvation-proof
                                      # aging; <= 0 disables aging)
    max_prefill_tokens_per_tick: int = 0
                                      # >0: admission/re-admission prefill is
                                      # budgeted — at most this many prompt
                                      # tokens run per tick, a longer tail
                                      # spans ticks as a resumable prefill
                                      # job, so a re-admitted giant cannot
                                      # stall lane-0 decode latency.
                                      # 0 = prefill to completion (legacy)
    # --- robustness (repro.serve.faults / repro.serve.audit) ---
    fault_plan: str = ""              # deterministic fault-injection spec
                                      # (faults.FaultPlan.parse grammar:
                                      # alloc@N,prefill@N,poison@T[:S],
                                      # clock+SEC@T,slow+SEC@T);
                                      # $REPRO_FAULTS outranks this;
                                      # "" = no injection
    audit_interval: int = 0           # audit the scheduler/pool invariants
                                      # every K ticks (audit.audit_scheduler,
                                      # raises AuditError on corruption);
                                      # $REPRO_AUDIT_INTERVAL outranks;
                                      # 0 disables
    # --- durability (repro.serve.durability; priority scheduler only) ---
    checkpoint_dir: str = ""          # directory for on-disk checkpoints +
                                      # the write-ahead request journal;
                                      # $REPRO_CHECKPOINT_DIR outranks;
                                      # "" disables durability entirely
    checkpoint_interval: int = 0      # write a checkpoint every K scheduler
                                      # ticks ($REPRO_CHECKPOINT_INTERVAL
                                      # outranks; 0 = no tick-driven
                                      # checkpoints — the journal still
                                      # captures every request event)
    checkpoint_interval_s: float = 0.0
                                      # ... and/or every S seconds of the
                                      # scheduler's (injectable) clock;
                                      # 0 disables the wall-clock trigger
    checkpoint_keep: int = 3          # keep-last-K checkpoint retention
                                      # (older ones + their journal epochs
                                      # are pruned after each publish)
    # --- observability (repro.serve.telemetry) ---
    telemetry: bool = False           # enable the metrics registry,
                                      # request tracing, and tick/kernel
                                      # profiling ($REPRO_TELEMETRY
                                      # outranks); stats counter views
                                      # count regardless
    trace_path: str = ""              # file that dump_trace() writes the
                                      # canonical-JSON trace export to
                                      # ($REPRO_TRACE_PATH outranks;
                                      # "" = return-only)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524288, 1,   "decode"),
}

ARCHS = [
    "hubert-xlarge", "mamba2-780m", "granite-moe-3b-a800m",
    "deepseek-v2-lite-16b", "recurrentgemma-2b", "qwen2-72b", "deepseek-67b",
    "qwen1.5-32b", "gemma-2b", "llama-3.2-vision-90b",
    # the paper's own evaluation models (1.58-bit):
    "llama3-8b-1.58bit", "falcon3-3b-1.58bit", "falcon3-10b-1.58bit",
]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason-if-not) per the assignment's skip rules."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k context requires "
                       "sub-quadratic attention (skip per assignment)")
    return True, ""


def _module_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch)}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)
