"""Checkpointing: atomic, resumable, elastic (mesh-independent on disk).

Format: a checkpoint directory ``step_<N>/`` holding
  * ``arrays.npz``  — flattened pytree leaves keyed by '/'-joined path
  * ``manifest.json`` — step, keys, shapes/dtypes, sha256 of arrays.npz
Writes go to ``step_<N>.tmp`` then ``os.rename`` (atomic on POSIX) — a crash
mid-save can never corrupt the latest checkpoint.  ``restore`` validates the
checksum, rebuilds the pytree, and ``device_put``s onto the *current* mesh's
shardings — so restarting on a different topology (elastic resize) reshards
transparently.  Background thread pool gives async save (train loop does not
block on I/O).
"""
from __future__ import annotations

import concurrent.futures as cf
import hashlib
import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

_EXEC = cf.ThreadPoolExecutor(max_workers=1)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         blocking: bool = True):
    """Save pytree; returns a future when blocking=False."""
    flat = _flatten(tree)

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **flat)
        digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
        manifest = {"step": step,
                    "keys": sorted(flat.keys()),
                    "shapes": {k: list(v.shape) for k, v in flat.items()},
                    "dtypes": {k: str(v.dtype) for k, v in flat.items()},
                    "sha256": digest}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)
        return final

    if blocking:
        return _write()
    return _EXEC.submit(_write)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, *,
            shardings_tree=None, validate: bool = True):
    """Load into the structure of ``tree_like``; reshard onto current mesh.

    Corrupt checkpoints (bad checksum) raise — callers fall back to the
    previous step (see fault.py).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    npz_path = os.path.join(d, "arrays.npz")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    if validate:
        digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {d} checksum mismatch")
    data = np.load(npz_path)

    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_leaves = (jax.tree.leaves(shardings_tree)
                    if shardings_tree is not None else [None] * len(paths))
    leaves = []
    for (path, like), shard in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        if shard is not None:
            leaves.append(jax.device_put(arr.astype(like.dtype), shard))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
