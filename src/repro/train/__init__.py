"""Subsystem package."""
