"""Training step factory: QAT forward/backward + AdamW/Lion, gradient
accumulation (microbatching via lax.scan), remat, optional int8
error-feedback gradient compression, mixed bf16/fp32.

``make_train_step(cfg, tcfg, mesh)`` returns (jitted_step, in/out shardings).
The step is pure-global (pjit): batch enters DP-sharded, params FSDP×TP
sharded; XLA inserts all-gathers/reduce-scatters per GSPMD.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, TrainConfig
from repro.models import transformer as tfm
from repro.parallel import sharding as shd
from repro.train import optimizer as opt


def train_state_init(cfg: ModelConfig, tcfg: TrainConfig, key):
    params = tfm.init_params(cfg, key)
    return {"params": params, "opt": opt.init_opt_state(params, tcfg)}


def _split_microbatches(batch: dict, n: int) -> dict:
    return jax.tree.map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_loss(cfg: ModelConfig, tcfg: TrainConfig):
    remat = tcfg.remat != "none"

    def loss(params, mb):
        return tfm.loss_fn(params, mb, cfg, quantize=cfg.quant != "none",
                           remat=remat)
    return loss


def train_step(state: dict, batch: dict, *, cfg: ModelConfig,
               tcfg: TrainConfig) -> tuple:
    """One optimizer step (with grad accumulation over microbatches)."""
    loss = make_loss(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss, has_aux=True)

    if tcfg.microbatches > 1:
        mbs = _split_microbatches(batch, tcfg.microbatches)

        def acc(carry, mb):
            gsum, lsum = carry
            (l, (ce, aux)), g = grad_fn(state["params"], mb)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (gsum, lsum + ce), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          state["params"])
        (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)),
                                       mbs)
        grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
        ce = lsum / tcfg.microbatches
        aux = jnp.zeros((), jnp.float32)
    else:
        (l, (ce, aux)), grads = grad_fn(state["params"], batch)

    if tcfg.grad_compression == "int8_ef":
        from repro.parallel.collectives import ef_compress_tree
        grads = ef_compress_tree(grads)

    new_params, new_opt, gnorm = opt.apply_updates(
        state["params"], grads, state["opt"], tcfg)
    metrics = {"loss": ce, "aux": aux, "grad_norm": gnorm,
               "lr": opt.lr_schedule(tcfg, state["opt"].step)}
    return {"params": new_params, "opt": new_opt}, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    state_abstract, batch_abstract):
    """Build the jitted, sharded train step + its sharding trees."""
    p_specs = shd.param_pspecs(state_abstract["params"], mesh)
    state_specs = {
        "params": p_specs,
        "opt": opt.OptState(
            step=P(),
            mu=jax.tree.map(lambda s: s, p_specs),
            nu=jax.tree.map(lambda s: s, p_specs)
            if tcfg.optimizer != "lion" else
            jax.tree.map(lambda s: P(), state_abstract["opt"].nu)),
    }
    batch_specs = shd.batch_pspecs(batch_abstract, mesh)
    step = functools.partial(train_step, cfg=cfg, tcfg=tcfg)
    jitted = jax.jit(
        step,
        in_shardings=(shd.shardings(state_specs, mesh),
                      shd.shardings(batch_specs, mesh)),
        out_shardings=(shd.shardings(state_specs, mesh), None),
        donate_argnums=(0,),
    )
    return jitted, state_specs, batch_specs
