"""Fault tolerance & elasticity for long-running multi-pod jobs.

The container is single-process, so multi-host failure handling is expressed
as policy + mechanism with the failure *signals* injectable (and covered by
tests via injection):

  * ``FaultManager.run`` — supervised step loop: periodic async checkpoints,
    automatic restore-and-resume on exceptions (falling back across corrupt
    checkpoints), bounded restart budget.
  * ``Heartbeat`` / ``StragglerPolicy`` — per-host heartbeat table; hosts
    silent for > timeout are declared dead (triggering elastic downsize);
    hosts persistently slower than ``straggler_factor`` × median step time
    are flagged for eviction — mirroring the Borg/TPU-pod babysitter design.
  * Elastic resize = restore the latest checkpoint onto a *new* mesh:
    checkpoints are stored mesh-independent (see checkpoint.py), so resuming
    on fewer/more data-parallel replicas is a restore with different
    shardings + a deterministic data stream keyed by step (see data.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.train import checkpoint as ckpt


@dataclass
class Heartbeat:
    timeout_s: float = 60.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, host: int, t: Optional[float] = None):
        self.last_seen[host] = time.monotonic() if t is None else t

    def dead_hosts(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]


@dataclass
class StragglerPolicy:
    """Flag hosts whose step time is persistently above factor × median."""
    factor: float = 1.5
    window: int = 20
    times: dict = field(default_factory=dict)

    def record(self, host: int, step_time: float):
        self.times.setdefault(host, []).append(step_time)
        self.times[host] = self.times[host][-self.window:]

    def stragglers(self) -> list[int]:
        if len(self.times) < 2:
            return []
        med = np.median([np.median(v) for v in self.times.values()])
        return [h for h, v in self.times.items()
                if len(v) >= self.window // 2 and np.median(v) > self.factor * med]


class FaultManager:
    """Supervised training loop with checkpoint/restart semantics."""

    def __init__(self, ckpt_dir: str, *, checkpoint_every: int = 100,
                 keep: int = 3, max_restarts: int = 5):
        self.ckpt_dir = ckpt_dir
        self.every = checkpoint_every
        self.keep = keep
        self.max_restarts = max_restarts
        self.restarts = 0
        self._pending = None

    # -- checkpoint mechanics -------------------------------------------------
    def maybe_save(self, step: int, state, *, blocking: bool = False):
        if step % self.every == 0 and step > 0:
            if self._pending is not None and not self._pending.done():
                self._pending.result()            # backpressure: 1 in flight
            self._pending = None
            res = ckpt.save(self.ckpt_dir, step, state, keep=self.keep,
                            blocking=blocking)
            if not blocking:
                self._pending = res

    def finalize(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def restore_latest(self, state_like, shardings_tree=None):
        """Restore newest valid checkpoint, falling back across corrupt ones.

        Returns (step, state) or (0, None) when nothing restorable."""
        for step in sorted(ckpt.all_steps(self.ckpt_dir), reverse=True):
            try:
                state = ckpt.restore(self.ckpt_dir, step, state_like,
                                     shardings_tree=shardings_tree)
                return step, state
            except Exception:
                continue
        return 0, None

    # -- supervised loop ------------------------------------------------------
    def run(self, init_state, step_fn: Callable, batch_fn: Callable,
            total_steps: int, *, state_like=None, shardings_tree=None,
            on_metrics: Optional[Callable] = None):
        """step_fn(state, batch) -> (state, metrics); batch_fn(step) -> batch.

        Any exception triggers restore-from-checkpoint and resume; the data
        stream is step-addressed so no batch is skipped or repeated."""
        state = init_state
        step = 0
        while step < total_steps:
            try:
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch)
                if on_metrics:
                    on_metrics(step, metrics)
                step += 1
                self.maybe_save(step, state)
            except KeyboardInterrupt:
                raise
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                rstep, rstate = self.restore_latest(
                    state_like if state_like is not None else state,
                    shardings_tree)
                if rstate is None:
                    raise
                step, state = rstep, rstate
        self.finalize()
        return state
