"""Synthetic data pipeline: deterministic, step-addressable, host-sharded.

Batches are a pure function of (seed, step) — restart/elastic-resume replays
the exact token stream with no stored iterator state, and any host can
generate any shard (straggler work-stealing is trivial).  A background
prefetch thread keeps ``depth`` batches ready so the accelerator never waits
on generation.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.config import ModelConfig


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, step: int, *,
                    seed: int = 0) -> dict:
    """Markov-ish synthetic LM data (learnable: next token correlates)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    v = cfg.vocab_size
    base = rng.integers(0, v, size=(batch, seq + 1), dtype=np.int32)
    # inject learnable structure: with p=0.5, next token = (tok*7+3) % v
    nxt = (base[:, :-1] * 7 + 3) % v
    coin = rng.random((batch, seq)) < 0.5
    base[:, 1:] = np.where(coin, nxt, base[:, 1:])
    out = {"tokens": base[:, :-1], "labels": base[:, 1:]}
    if cfg.frontend == "audio_stub":
        out = {"embeds": rng.standard_normal(
                   (batch, seq, cfg.d_model), dtype=np.float32),
               "labels": rng.integers(0, v, size=(batch, seq),
                                      dtype=np.int32)}
    elif cfg.frontend == "vision_stub":
        out["image_embeds"] = rng.standard_normal(
            (batch, cfg.num_image_tokens, cfg.d_model),
            dtype=np.float32).astype(np.float32)
    return out


class Prefetcher:
    """Background thread generating (step -> batch) ahead of consumption."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 seed: int = 0, start_step: int = 0, depth: int = 2,
                 shardings=None):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        while not self._stop.is_set():
            b = synthetic_batch(self.cfg, self.batch, self.seq, self.step,
                                seed=self.seed)
            if self.shardings is not None:
                b = jax.tree.map(jax.device_put, b, self.shardings)
            try:
                self.q.put((self.step, b), timeout=1.0)
            except queue.Full:
                continue
            self.step += 1

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
