"""Optimizers (AdamW, Lion) as pure pytree transforms — no optax dependency.

State layout mirrors the param tree, so GSPMD shards optimizer moments
exactly like the FSDP-sharded params (ZeRO-1: each data shard owns the
moments of its param shard — no replication of optimizer state anywhere).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict          # unused (zeros-like scalars) for lion


def init_opt_state(params, tcfg: TrainConfig) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    if tcfg.optimizer == "lion":
        nu = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    else:
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=nu)


def lr_schedule(tcfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - tcfg.warmup_steps) /
                 jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * t))
    return tcfg.learning_rate * warm * cos


def _decay_mask(path) -> bool:
    """Weight decay only on matrices (skip norms, biases, scalars)."""
    names = {getattr(p, "key", None) for p in path}
    return not ({"scale", "bias", "b", "gate", "lam", "A_log", "D",
                 "dt_bias"} & names)


def apply_updates(params, grads, state: OptState, tcfg: TrainConfig):
    """-> (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)
    lr = lr_schedule(tcfg, state.step)
    b1, b2 = tcfg.b1, tcfg.b2
    step1 = state.step + 1

    if tcfg.optimizer == "lion":
        def upd(path, p, g, m):
            u = jnp.sign(b1 * m + (1 - b1) * g)
            if _decay_mask(path):
                u = u + tcfg.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * u
            new_m = b2 * m + (1 - b2) * g
            return new_p.astype(p.dtype), new_m
        out = jax.tree_util.tree_map_with_path(upd, params, grads, state.mu)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step1, new_mu, state.nu), gnorm

    # AdamW
    bc1 = 1 - b1 ** step1.astype(jnp.float32)
    bc2 = 1 - b2 ** step1.astype(jnp.float32)

    def upd(path, p, g, m, v):
        m1 = b1 * m + (1 - b1) * g
        v1 = b2 * v + (1 - b2) * jnp.square(g)
        u = (m1 / bc1) / (jnp.sqrt(v1 / bc2) + 1e-8)
        if _decay_mask(path):
            u = u + tcfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m1, v1

    out = jax.tree_util.tree_map_with_path(upd, params, grads, state.mu,
                                           state.nu)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is3)
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=is3)
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=is3)
    return new_params, OptState(step1, new_mu, new_nu), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
