"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables."""
import glob
import json
import sys

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ORDER_ARCHS = ["hubert-xlarge", "mamba2-780m", "granite-moe-3b-a800m",
               "deepseek-v2-lite-16b", "recurrentgemma-2b", "qwen2-72b",
               "deepseek-67b", "qwen1.5-32b", "gemma-2b",
               "llama-3.2-vision-90b"]


def fmt_s(x):
    if x == 0:
        return "0"
    for unit, div in (("s", 1), ("ms", 1e-3), ("us", 1e-6), ("ns", 1e-9)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.1e}s"


def load(mesh):
    out = {}
    for f in glob.glob("experiments/dryrun/*.json"):
        d = json.load(open(f))
        if d["mesh"] != mesh:
            continue
        out[(d["arch"], d["shape"])] = d
    return out


def table(mesh, full=True):
    recs = load(mesh)
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | status | FLOPs/chip | bytes/chip | coll B/chip |"
          " compute | memory | collective | dominant | 6ND/HLO | roofline"
          " frac | HBM/chip |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ORDER_ARCHS:
        for shape in ORDER_SHAPES:
            d = recs.get((arch, shape))
            if d is None:
                continue
            if d["status"] == "skipped":
                print(f"| {arch} | {shape} | SKIP: {d['reason'][:48]} |"
                      + " |" * 10)
                continue
            if d["status"] == "error":
                print(f"| {arch} | {shape} | ERROR |" + " |" * 10)
                continue
            r = d["roofline"]
            hbm = (d["memory"]["argument_bytes"] + d["memory"]["temp_bytes"]
                   + d["memory"]["output_bytes"]) / d["chips"] / 2**30
            print(f"| {arch} | {shape} | ok | {r['hlo_flops']:.2e} |"
                  f" {r['hlo_bytes']:.2e} | {r['coll_bytes']:.2e} |"
                  f" {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} |"
                  f" {fmt_s(r['collective_s'])} | **{r['dominant']}** |"
                  f" {min(r['useful_ratio'],99):.3f} |"
                  f" {r['roofline_fraction']:.3f} | {hbm:.2f}GiB |")


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    table(mesh)
