#!/usr/bin/env bash
# CI entry point: tier-1 suite + serve-path smoke benchmark.
#
#   scripts/ci.sh            # full tier-1 + smoke bench
#   scripts/ci.sh --fast     # tier-1 only
#
# The smoke benchmark exercises the real serve path (dispatch -> Pallas
# kernel, interpret mode on CPU) at small shapes and asserts backend
# equality; the committed BENCH_serve.json is produced by the full run
# (`python benchmarks/run.py --only serve`) and tracked per PR.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo "== serve smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only serve --smoke \
        --json /tmp/BENCH_serve_smoke.json
fi

echo "CI OK"
