#!/usr/bin/env bash
# CI entry point: tier-1 suite + serve-path smoke benchmarks.
#
#   scripts/ci.sh            # fast tier (-m "not slow") + smoke benches
#   scripts/ci.sh --fast     # fast-tier tests only, no benches
#   CI_SLOW=1 scripts/ci.sh  # FULL tier-1 (incl. slow model-family parity
#                            # sweeps) + smoke benches
#
# Interpret-mode Pallas makes the full suite exceed the container's CI
# budget, so the heavy cross-family parity sweeps are marked `slow`
# (pyproject [tool.pytest.ini_options].markers) and excluded by default;
# they still run under `CI_SLOW=1` and under the bare tier-1 command
# (`python -m pytest -x -q`, no marker filter) used for release checks.
#
# The smoke benchmarks exercise the real serve path (dispatch -> Pallas
# kernel, interpret mode on CPU) at small shapes: serve asserts backend
# equality, prefill asserts chunked-prefill parity vs the scan reference
# and scheduler-vs-per-request token equality, paged asserts paged-vs-
# dense token equality plus a shared-prefix admission the dense layout
# rejects, paged_attn asserts kernel-vs-gather decode token equality and
# the per-step KV bytes accounting, request_plane asserts greedy parity
# under overcommit + preemption and the deterministic policy outcomes
# (no preemption at 1.0x, at least one at 1.5x, expired deadlines shed).
# chaos runs a seeded multi-seam fault plan (allocator, prefill, NaN
# poisoning, clock jumps) over mixed traffic with the invariant auditor
# at interval 1 and asserts zero leaks, terminal states everywhere,
# bitwise parity for unfaulted requests, and a bitwise-continuous
# snapshot/restore resume.  durability kills the plane at a seeded
# random tick with torn/flip/fsync disk faults live and asserts
# recovery from disk (newest valid checkpoint + journal replay) is
# leak-free and bitwise-continuous, plus a corrupted-newest-checkpoint
# fallback leg.  telemetry runs identical traffic with the observability
# plane off vs on and asserts bitwise token parity across modes plus a
# well-formed trace export; its disabled-mode no-op overhead micro-gate
# keeps the default path free.
# Timing-sensitive perf comparisons (chunked > scan, paged >= dense,
# 1.5x >= 1.0x, telemetry-off <= telemetry-on) are recorded-and-warned
# on a loaded machine;
# BENCH_STRICT=1 restores the hard asserts.  The asyncio frontend tests
# in tests/test_frontend.py carry their own asyncio.wait_for timeout
# guard, so a dead serve loop fails fast instead of hanging this script.
# The committed BENCH_serve.json / BENCH_prefill.json are produced by the
# full runs (`python benchmarks/run.py --only
# serve|request_plane|prefill|paged|paged_attn|chaos|durability|telemetry`,
# merge-preserving
# writes into both JSONs) and tracked per PR.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARKER=(-m "not slow")
if [[ "${CI_SLOW:-0}" == "1" ]]; then
    MARKER=()
    echo "== tier-1: pytest (full, CI_SLOW=1) =="
else
    echo "== tier-1: pytest (fast tier; CI_SLOW=1 for the full pass) =="
fi
# ${arr[@]+...} guard: expanding an empty array trips `set -u` on bash < 4.4
python -m pytest -x -q ${MARKER[@]+"${MARKER[@]}"}

# Static invariant gate (both tiers): tile/VMEM budgets over the whole
# config zoo, host/device boundary hygiene, quantized dtype flow, env-doc
# drift.  Fails on any finding not justified in reprolint_baseline.json.
echo "== reprolint: python -m repro.analysis --fail-on-findings =="
python -m repro.analysis --fail-on-findings

# Rerun the serve-plane suites with the invariant auditor on EVERY tick:
# a green pass here proves the allocator/table/position books stay
# consistent at each step of every covered scenario, not just at the
# asserted endpoints.  (Interval 1 is too slow for the default suite;
# the env var outranks ServeConfig.audit_interval.)
echo "== serve-plane suites under REPRO_AUDIT_INTERVAL=1 =="
REPRO_AUDIT_INTERVAL=1 python -m pytest -x -q ${MARKER[@]+"${MARKER[@]}"} \
    tests/test_serve.py tests/test_paged.py tests/test_frontend.py \
    tests/test_chaos.py tests/test_durability.py

if [[ "${1:-}" != "--fast" ]]; then
    echo "== serve smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only serve --smoke \
        --json /tmp/BENCH_serve_smoke.json
    echo "== prefill smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only prefill --smoke \
        --prefill-json /tmp/BENCH_prefill_smoke.json
    echo "== paged smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only paged --smoke \
        --prefill-json /tmp/BENCH_prefill_smoke.json
    echo "== paged-attention smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only paged_attn --smoke \
        --prefill-json /tmp/BENCH_prefill_smoke.json
    echo "== request-plane smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only request_plane --smoke \
        --json /tmp/BENCH_serve_smoke.json
    echo "== chaos smoke soak =="
    PYTHONPATH="src:." python benchmarks/run.py --only chaos --smoke \
        --json /tmp/BENCH_serve_smoke.json
    echo "== durability smoke soak =="
    PYTHONPATH="src:." python benchmarks/run.py --only durability --smoke \
        --json /tmp/BENCH_serve_smoke.json
    echo "== telemetry smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only telemetry --smoke \
        --json /tmp/BENCH_serve_smoke.json
fi

echo "CI OK"
