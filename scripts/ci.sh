#!/usr/bin/env bash
# CI entry point: tier-1 suite + serve-path smoke benchmark.
#
#   scripts/ci.sh            # full tier-1 + smoke bench
#   scripts/ci.sh --fast     # tier-1 only
#
# The smoke benchmarks exercise the real serve path (dispatch -> Pallas
# kernel, interpret mode on CPU) at small shapes: serve asserts backend
# equality, prefill asserts chunked-prefill parity vs the scan reference
# and scheduler-vs-per-request token equality.  The committed
# BENCH_serve.json / BENCH_prefill.json are produced by the full runs
# (`python benchmarks/run.py --only serve|prefill`) and tracked per PR.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo "== serve smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only serve --smoke \
        --json /tmp/BENCH_serve_smoke.json
    echo "== prefill smoke benchmark =="
    PYTHONPATH="src:." python benchmarks/run.py --only prefill --smoke \
        --prefill-json /tmp/BENCH_prefill_smoke.json
fi

echo "CI OK"
