"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Container scale: the paper
benches n up to 2^16 in C++; a single CPU core here gets honest numbers up to
n=2^12-2^13 (pass --large for the paper's full range).  Each row's `derived`
column carries the headline quantity of that figure (speedup, ratio, k*).

  fig4_native   RSR / RSR++ / Standard matvec time vs n      (paper Fig. 4)
  fig5_memory   index bytes vs dense matrix bytes            (paper Fig. 5)
  fig9_opt_k    measured best k vs Eq.6/7 prediction         (paper Fig. 9)
  fig10_pp      RSR++ vs RSR step-2 improvement              (paper Fig. 10)
  fig11_numpy   RSR vs NumPy BLAS dot, binary+ternary        (paper Fig. 11)
  fig6_llm      per-layer decode matvec at the paper's LLM
                matrix sizes (llama3-8b / falcon3)           (paper Fig. 6)
  table1_tpu    TPU-kernel roofline projection for the same
                layers (replaces the paper's GPU Table 1;
                no GPU here — v5e is the target)             (paper Tab. 1)
  engine_e2e    end-to-end reduced-model decode: RSR serve
                vs dense serve through the Engine            (paper §5.3)
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.rsr_numpy import (bin_matrix_np, index_bytes_np,
                                  naive_matvec_np, preprocess_np,
                                  rsr_matvec_np, standard_matvec_np)

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def _time(fn, reps=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6         # µs


def _best_k(n, m, v, b, ks, reps=3):
    best, best_us = None, float("inf")
    for k in ks:
        perm, seg, _ = preprocess_np(b, k)
        us = _time(lambda: rsr_matvec_np(v, perm, seg, k, m), reps=reps)
        if us < best_us:
            best, best_us = k, us
    return best, best_us


# ---------------------------------------------------------------------------

def fig4_native(ns):
    """RSR vs RSR++ vs Standard (naive, non-BLAS) — the paper's C++ setting."""
    rng = np.random.default_rng(0)
    for n in ns:
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        v = rng.standard_normal(n).astype(np.float32)
        k = max(4, int(np.log2(n)) - 3)
        perm, seg, _ = preprocess_np(b, k)
        bf = b.astype(np.float32)
        t_std = _time(lambda: naive_matvec_np(v, bf))
        t_rsr = _time(lambda: rsr_matvec_np(v, perm, seg, k, n))
        t_pp = _time(lambda: rsr_matvec_np(v, perm, seg, k, n,
                                           plus_plus=True))
        ref = rsr_matvec_np(v, perm, seg, k, n)
        assert np.allclose(ref, v @ bf, rtol=1e-3, atol=1e-2)
        emit(f"fig4_standard_n{n}", t_std, "baseline")
        emit(f"fig4_rsr_n{n}", t_rsr, f"speedup={t_std/t_rsr:.2f}x")
        emit(f"fig4_rsrpp_n{n}", t_pp, f"speedup={t_std/t_pp:.2f}x")


def fig5_memory(ns):
    for n in ns:
        rng = np.random.default_rng(1)
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        k = max(4, int(np.log2(n)) - 3)
        perm, seg, codes = preprocess_np(b, k)
        dense = n * n * 4                                   # f32 (paper Fig 5)
        idx = index_bytes_np(perm, seg)
        codes_b = codes.astype(np.uint8).nbytes if k <= 8 else codes.nbytes
        emit(f"fig5_memory_n{n}", 0.0,
             f"dense_f32={dense};index={idx};ratio={dense/idx:.2f}x;"
             f"codes={codes_b}")


def fig9_opt_k(ns):
    for n in ns:
        rng = np.random.default_rng(2)
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        v = rng.standard_normal(n).astype(np.float32)
        ks = range(2, max(4, int(np.log2(n))) + 1)
        k_star, us = _best_k(n, n, v, b, ks)
        from repro.core import optimal_k_rsrpp
        emit(f"fig9_optk_n{n}", us,
             f"k_measured={k_star};k_eq7={optimal_k_rsrpp(n)}")


def fig10_pp(ns):
    """RSR++ vs RSR on step 2 only (u · Bin_[k])."""
    for n in ns:
        k = max(4, int(np.log2(n)) - 3)
        rng = np.random.default_rng(3)
        u = rng.standard_normal((max(1, n // k), 2 ** k)).astype(np.float32)
        bink = bin_matrix_np(k)
        t_mat = _time(lambda: u @ bink, reps=20)

        def fold():
            x = u
            outs = []
            for _ in range(k):
                pairs = x.reshape(x.shape[0], -1, 2)
                outs.append(pairs[:, :, 1].sum(1))
                x = pairs.sum(2)
            return np.stack(outs[::-1], 1)
        t_fold = _time(fold, reps=20)
        imp = (t_mat - t_fold) / t_mat * 100
        # paper Fig 10 (scalar C++) sees ~25% from the O(2^k) fold; in NumPy
        # the k tiny BLAS-free passes lose to one sgemm on constant factors —
        # report both the measurement and the op-count theory (k·2^k vs 2^k).
        theory = k * 2 ** k / (2 ** (k + 1) - 2)
        emit(f"fig10_step2_n{n}", t_fold,
             f"improvement={imp:.1f}%;theory_op_ratio={theory:.2f}x")


def fig11_numpy(ns):
    """RSR vs np.dot (BLAS) for binary AND ternary weights."""
    rng = np.random.default_rng(4)
    for n in ns:
        v = rng.standard_normal(n).astype(np.float32)
        k = max(4, int(np.log2(n)) - 3)
        # binary
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        perm, seg, _ = preprocess_np(b, k)
        t_np = _time(lambda: standard_matvec_np(v, b.astype(np.float32)))
        t_rsr = _time(lambda: rsr_matvec_np(v, perm, seg, k, n))
        emit(f"fig11_binary_n{n}", t_rsr,
             f"numpy_us={t_np:.1f};speedup={t_np/t_rsr:.2f}x")
        # ternary (Prop 2.1: two binary passes)
        a = rng.integers(-1, 2, size=(n, n)).astype(np.int8)
        p1, s1, _ = preprocess_np((a == 1).astype(np.int8), k)
        p2, s2, _ = preprocess_np((a == -1).astype(np.int8), k)
        t_np_t = _time(lambda: standard_matvec_np(v, a.astype(np.float32)))
        t_rsr_t = _time(lambda: rsr_matvec_np(v, p1, s1, k, n) -
                        rsr_matvec_np(v, p2, s2, k, n))
        emit(f"fig11_ternary_n{n}", t_rsr_t,
             f"numpy_us={t_np_t:.1f};speedup={t_np_t/t_rsr_t:.2f}x")


# the paper's §5.3 LLM layer sizes (llama3-8b: d=4096 ff=14336;
# falcon3: d=3072 ff=9216/23040)
LLM_LAYERS = {
    "llama3-8b": [(4096, 4096), (4096, 14336), (14336, 4096)],
    "falcon3-3b": [(3072, 3072), (3072, 9216), (9216, 3072)],
    "falcon3-10b": [(3072, 3072), (3072, 23040), (23040, 3072)],
}


def fig6_llm():
    """Per-layer decode matvec at true paper matrix sizes, CPU."""
    rng = np.random.default_rng(5)
    for model, layers in LLM_LAYERS.items():
        t_std_total = t_rsr_total = 0.0
        for (n, m) in layers:
            a = rng.integers(-1, 2, size=(n, m)).astype(np.int8)
            v = rng.standard_normal(n).astype(np.float32)
            k = 8
            p1, s1, _ = preprocess_np((a == 1).astype(np.int8), k)
            p2, s2, _ = preprocess_np((a == -1).astype(np.int8), k)
            t_std = _time(lambda: standard_matvec_np(v, a.astype(np.float32)),
                          reps=3)
            t_rsr = _time(lambda: rsr_matvec_np(v, p1, s1, k, m) -
                          rsr_matvec_np(v, p2, s2, k, m), reps=3)
            t_std_total += t_std
            t_rsr_total += t_rsr
        emit(f"fig6_{model}", t_rsr_total,
             f"standard_us={t_std_total:.0f};"
             f"speedup={t_std_total/t_rsr_total:.2f}x")


def table1_tpu():
    """TPU v5e roofline projection of the Pallas kernels for the same layers
    (replaces the paper's GPU Table 1; see DESIGN.md §2 for the model).
    dense-2bit: max(bytes/4/819GBps, 2·n·m/197T);  RSR direct k=5:
    max(n·m/5B/819GBps, 2·(3^5/5)·n·m/394T int8-MXU)."""
    for model, layers in LLM_LAYERS.items():
        t_dense = t_rsr = 0.0
        for (n, m) in layers:
            nm = n * m
            t_dense += max(nm / 4 / 819e9, 2 * nm / 197e12) * 1e6
            t_rsr += max(nm / 5 / 819e9, 2 * (243 / 5) * nm / 394e12) * 1e6
        emit(f"table1_tpu_{model}", t_rsr,
             f"dense2bit_us={t_dense:.2f};ratio={t_dense/t_rsr:.2f}x")


def engine_e2e():
    """Reduced-model end-to-end decode: RSR serve vs dense serve (§5.3)."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.config import ServeConfig, get_config
    from repro.models import transformer as tfm
    from repro.serve.engine import Engine
    cfg = dataclasses.replace(get_config("falcon3-3b-1.58bit").reduced(),
                              vocab_size=256, num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_seq_len=64, batch_size=2)
    e_rsr = Engine(cfg, tfm.serve_params(params, cfg), scfg)
    e_dense = Engine(cfg, tfm.serve_params(
        params, dataclasses.replace(cfg, rsr_serve=False)), scfg)
    prompts = jnp.ones((2, 8), jnp.int32)
    o1 = e_rsr.generate(prompts, 8)          # warmup+compile
    o2 = e_dense.generate(prompts, 8)
    assert np.array_equal(o1, o2), "RSR and dense decodes must match"
    e_rsr.reset()
    t1 = _time(lambda: e_rsr.generate(prompts, 8), reps=2, warmup=0)
    e_dense.reset()
    t2 = _time(lambda: e_dense.generate(prompts, 8), reps=2, warmup=0)
    emit("engine_e2e_rsr", t1, f"dense_us={t2:.0f};outputs_equal=True")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true",
                    help="paper-scale n (2^11..2^15); slow on 1 core")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    ns = [2 ** e for e in ((11, 12, 13, 14, 15) if args.large
                           else (9, 10, 11, 12))]
    print("name,us_per_call,derived")
    tables = {
        "fig4": lambda: fig4_native(ns),
        "fig5": lambda: fig5_memory(ns),
        "fig9": lambda: fig9_opt_k(ns[:2]),
        "fig10": lambda: fig10_pp(ns),
        "fig11": lambda: fig11_numpy(ns),
        "fig6": fig6_llm,
        "table1": table1_tpu,
        "engine": engine_e2e,
    }
    for name, fn in tables.items():
        if args.only and args.only not in name:
            continue
        fn()


if __name__ == "__main__":
    main()
