"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Container scale: the paper
benches n up to 2^16 in C++; a single CPU core here gets honest numbers up to
n=2^12-2^13 (pass --large for the paper's full range).  Each row's `derived`
column carries the headline quantity of that figure (speedup, ratio, k*).

  fig4_native   RSR / RSR++ / Standard matvec time vs n      (paper Fig. 4)
  fig5_memory   index bytes vs dense matrix bytes            (paper Fig. 5)
  fig9_opt_k    measured best k vs Eq.6/7 prediction         (paper Fig. 9)
  fig10_pp      RSR++ vs RSR step-2 improvement              (paper Fig. 10)
  fig11_numpy   RSR vs NumPy BLAS dot, binary+ternary        (paper Fig. 11)
  fig6_llm      per-layer decode matvec at the paper's LLM
                matrix sizes (llama3-8b / falcon3)           (paper Fig. 6)
  table1_tpu    TPU-kernel roofline projection for the same
                layers (replaces the paper's GPU Table 1;
                no GPU here — v5e is the target)             (paper Tab. 1)
  engine_e2e    end-to-end reduced-model decode: RSR serve
                vs dense serve through the Engine            (paper §5.3)
  serve_bench   the serve-path perf trajectory: per-linear
                latency (dense vs scatter vs Pallas vs
                Pallas+packed) at true model layer shapes,
                engine decode tokens/s per backend, and the
                packed-code bits/weight budget — written to
                BENCH_serve.json (tracked per PR)
  request_plane the priority request plane under pool pressure:
                preemption / shed / re-admission counts, p50/p99
                completion latency per priority lane, tokens/s
                at 1.5x vs 1.0x overcommit, with hard greedy-
                parity and policy-outcome asserts — written to
                the ``request_plane`` section of BENCH_serve.json
  prefill_bench the prefill-path trajectory: per-linear
                amortization at true layer shapes as rows grow
                (1 -> B·chunk, the prefill tile regime),
                chunked engine prefill tokens/s vs the old
                decode-step-scan path with cache/logit parity
                asserted, and continuous-batching scheduler
                throughput over mixed prefill+decode traffic
                with per-request token equality — written to
                BENCH_prefill.json (tracked per PR)
  paged_bench   the block-paged KV trajectory: paged-vs-dense
                token parity, shared-prefix admission hit-rate
                and scheduler tokens/s vs dense re-prefill, and
                an equal-KV-memory mixed-traffic run the dense
                layout must reject at submit() — written to the
                ``paged`` section of BENCH_prefill.json
  durability    the durable serve plane: seeded kill-at-a-
                random-tick soak with torn/flip/fsync disk
                faults live, recovery latency / journal-replay
                length / checkpoint size per seed, a corrupted-
                newest-checkpoint fallback leg, hard asserts on
                zero leaks + bitwise greedy continuity — written
                to the ``durability`` section of BENCH_serve.json
  telemetry     the observability plane: identical traffic run
                with telemetry off vs on (hard token-parity
                across modes), per-lane queue/prefill/decode
                latency attribution computed from the request
                trace, and a disabled-mode no-op overhead
                micro-gate — written to the ``telemetry``
                section of BENCH_serve.json
  paged_attn_bench  the in-place paged-attention trajectory:
                per-decode-step KV bytes moved (kernel vs the
                gather path's materialize-then-score) at true
                serve geometries, kernel-vs-gather token parity
                on the reduced config, and decode tokens/s per
                paged backend — written to the ``paged_attn``
                section of BENCH_prefill.json

Perf-comparison asserts (chunked > scan, paged >= dense) are RECORDED AND
WARNED by default — on a loaded CPU they are scheduler noise, not signal —
and only hard-fail under ``BENCH_STRICT=1`` (the idle-machine/TPU setting).
Correctness asserts (token parity, capacity accounting, bytes accounting)
are always hard.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.rsr_numpy import (bin_matrix_np, index_bytes_np,
                                  naive_matvec_np, preprocess_np,
                                  rsr_matvec_np, standard_matvec_np)

ROWS: list[tuple[str, float, str]] = []

PERF_WARNINGS: list[str] = []


def perf_gate(cond: bool, msg: str, result: dict | None = None) -> bool:
    """Timing-sensitive comparison: hard assert under BENCH_STRICT=1, else
    recorded in the result payload + warned (a loaded CPU must not fail CI
    smoke over a scheduler hiccup).  Returns ``cond``."""
    if cond:
        return True
    if os.environ.get("BENCH_STRICT") == "1":
        raise AssertionError(msg)
    print(f"WARN (perf gate, BENCH_STRICT=0): {msg}", flush=True)
    PERF_WARNINGS.append(msg)
    if result is not None:
        result.setdefault("perf_warnings", []).append(msg)
    return False


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def _time(fn, reps=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6         # µs


def _best_k(n, m, v, b, ks, reps=3):
    best, best_us = None, float("inf")
    for k in ks:
        perm, seg, _ = preprocess_np(b, k)
        us = _time(lambda: rsr_matvec_np(v, perm, seg, k, m), reps=reps)
        if us < best_us:
            best, best_us = k, us
    return best, best_us


# ---------------------------------------------------------------------------

def fig4_native(ns):
    """RSR vs RSR++ vs Standard (naive, non-BLAS) — the paper's C++ setting."""
    rng = np.random.default_rng(0)
    for n in ns:
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        v = rng.standard_normal(n).astype(np.float32)
        k = max(4, int(np.log2(n)) - 3)
        perm, seg, _ = preprocess_np(b, k)
        bf = b.astype(np.float32)
        t_std = _time(lambda: naive_matvec_np(v, bf))
        t_rsr = _time(lambda: rsr_matvec_np(v, perm, seg, k, n))
        t_pp = _time(lambda: rsr_matvec_np(v, perm, seg, k, n,
                                           plus_plus=True))
        ref = rsr_matvec_np(v, perm, seg, k, n)
        assert np.allclose(ref, v @ bf, rtol=1e-3, atol=1e-2)
        emit(f"fig4_standard_n{n}", t_std, "baseline")
        emit(f"fig4_rsr_n{n}", t_rsr, f"speedup={t_std/t_rsr:.2f}x")
        emit(f"fig4_rsrpp_n{n}", t_pp, f"speedup={t_std/t_pp:.2f}x")


def fig5_memory(ns):
    for n in ns:
        rng = np.random.default_rng(1)
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        k = max(4, int(np.log2(n)) - 3)
        perm, seg, codes = preprocess_np(b, k)
        dense = n * n * 4                                   # f32 (paper Fig 5)
        idx = index_bytes_np(perm, seg)
        codes_b = codes.astype(np.uint8).nbytes if k <= 8 else codes.nbytes
        emit(f"fig5_memory_n{n}", 0.0,
             f"dense_f32={dense};index={idx};ratio={dense/idx:.2f}x;"
             f"codes={codes_b}")


def fig9_opt_k(ns):
    for n in ns:
        rng = np.random.default_rng(2)
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        v = rng.standard_normal(n).astype(np.float32)
        ks = range(2, max(4, int(np.log2(n))) + 1)
        k_star, us = _best_k(n, n, v, b, ks)
        from repro.core import optimal_k_rsrpp
        emit(f"fig9_optk_n{n}", us,
             f"k_measured={k_star};k_eq7={optimal_k_rsrpp(n)}")


def fig10_pp(ns):
    """RSR++ vs RSR on step 2 only (u · Bin_[k])."""
    for n in ns:
        k = max(4, int(np.log2(n)) - 3)
        rng = np.random.default_rng(3)
        u = rng.standard_normal((max(1, n // k), 2 ** k)).astype(np.float32)
        bink = bin_matrix_np(k)
        t_mat = _time(lambda: u @ bink, reps=20)

        def fold():
            x = u
            outs = []
            for _ in range(k):
                pairs = x.reshape(x.shape[0], -1, 2)
                outs.append(pairs[:, :, 1].sum(1))
                x = pairs.sum(2)
            return np.stack(outs[::-1], 1)
        t_fold = _time(fold, reps=20)
        imp = (t_mat - t_fold) / t_mat * 100
        # paper Fig 10 (scalar C++) sees ~25% from the O(2^k) fold; in NumPy
        # the k tiny BLAS-free passes lose to one sgemm on constant factors —
        # report both the measurement and the op-count theory (k·2^k vs 2^k).
        theory = k * 2 ** k / (2 ** (k + 1) - 2)
        emit(f"fig10_step2_n{n}", t_fold,
             f"improvement={imp:.1f}%;theory_op_ratio={theory:.2f}x")


def fig11_numpy(ns):
    """RSR vs np.dot (BLAS) for binary AND ternary weights."""
    rng = np.random.default_rng(4)
    for n in ns:
        v = rng.standard_normal(n).astype(np.float32)
        k = max(4, int(np.log2(n)) - 3)
        # binary
        b = rng.integers(0, 2, size=(n, n)).astype(np.int8)
        perm, seg, _ = preprocess_np(b, k)
        t_np = _time(lambda: standard_matvec_np(v, b.astype(np.float32)))
        t_rsr = _time(lambda: rsr_matvec_np(v, perm, seg, k, n))
        emit(f"fig11_binary_n{n}", t_rsr,
             f"numpy_us={t_np:.1f};speedup={t_np/t_rsr:.2f}x")
        # ternary (Prop 2.1: two binary passes)
        a = rng.integers(-1, 2, size=(n, n)).astype(np.int8)
        p1, s1, _ = preprocess_np((a == 1).astype(np.int8), k)
        p2, s2, _ = preprocess_np((a == -1).astype(np.int8), k)
        t_np_t = _time(lambda: standard_matvec_np(v, a.astype(np.float32)))
        t_rsr_t = _time(lambda: rsr_matvec_np(v, p1, s1, k, n) -
                        rsr_matvec_np(v, p2, s2, k, n))
        emit(f"fig11_ternary_n{n}", t_rsr_t,
             f"numpy_us={t_np_t:.1f};speedup={t_np_t/t_rsr_t:.2f}x")


# the paper's §5.3 LLM layer sizes (llama3-8b: d=4096 ff=14336;
# falcon3: d=3072 ff=9216/23040)
LLM_LAYERS = {
    "llama3-8b": [(4096, 4096), (4096, 14336), (14336, 4096)],
    "falcon3-3b": [(3072, 3072), (3072, 9216), (9216, 3072)],
    "falcon3-10b": [(3072, 3072), (3072, 23040), (23040, 3072)],
}


def fig6_llm():
    """Per-layer decode matvec at true paper matrix sizes, CPU."""
    rng = np.random.default_rng(5)
    for model, layers in LLM_LAYERS.items():
        t_std_total = t_rsr_total = 0.0
        for (n, m) in layers:
            a = rng.integers(-1, 2, size=(n, m)).astype(np.int8)
            v = rng.standard_normal(n).astype(np.float32)
            k = 8
            p1, s1, _ = preprocess_np((a == 1).astype(np.int8), k)
            p2, s2, _ = preprocess_np((a == -1).astype(np.int8), k)
            t_std = _time(lambda: standard_matvec_np(v, a.astype(np.float32)),
                          reps=3)
            t_rsr = _time(lambda: rsr_matvec_np(v, p1, s1, k, m) -
                          rsr_matvec_np(v, p2, s2, k, m), reps=3)
            t_std_total += t_std
            t_rsr_total += t_rsr
        emit(f"fig6_{model}", t_rsr_total,
             f"standard_us={t_std_total:.0f};"
             f"speedup={t_std_total/t_rsr_total:.2f}x")


def table1_tpu():
    """TPU v5e roofline projection of the Pallas kernels for the same layers
    (replaces the paper's GPU Table 1; see DESIGN.md §2 for the model).
    dense-2bit: max(bytes/4/819GBps, 2·n·m/197T);  RSR direct k=5:
    max(n·m/5B/819GBps, 2·(3^5/5)·n·m/394T int8-MXU)."""
    for model, layers in LLM_LAYERS.items():
        t_dense = t_rsr = 0.0
        for (n, m) in layers:
            nm = n * m
            t_dense += max(nm / 4 / 819e9, 2 * nm / 197e12) * 1e6
            t_rsr += max(nm / 5 / 819e9, 2 * (243 / 5) * nm / 394e12) * 1e6
        emit(f"table1_tpu_{model}", t_rsr,
             f"dense2bit_us={t_dense:.2f};ratio={t_dense/t_rsr:.2f}x")


def engine_e2e():
    """Reduced-model end-to-end decode: RSR serve vs dense serve (§5.3)."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.config import ServeConfig, get_config
    from repro.models import transformer as tfm
    from repro.serve.engine import Engine
    cfg = dataclasses.replace(get_config("falcon3-3b-1.58bit").reduced(),
                              vocab_size=256, num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_seq_len=64, batch_size=2)
    e_rsr = Engine(cfg, tfm.serve_params(params, cfg), scfg)
    e_dense = Engine(cfg, tfm.serve_params(
        params, dataclasses.replace(cfg, rsr_serve=False)), scfg)
    prompts = jnp.ones((2, 8), jnp.int32)
    o1 = e_rsr.generate(prompts, 8)          # warmup+compile
    o2 = e_dense.generate(prompts, 8)
    assert np.array_equal(o1, o2), "RSR and dense decodes must match"
    e_rsr.reset()
    t1 = _time(lambda: e_rsr.generate(prompts, 8), reps=2, warmup=0)
    e_dense.reset()
    t2 = _time(lambda: e_dense.generate(prompts, 8), reps=2, warmup=0)
    emit("engine_e2e_rsr", t1, f"dense_us={t2:.0f};outputs_equal=True")


def serve_bench(json_path: str = "BENCH_serve.json", smoke: bool = False):
    """Serve-path trajectory benchmark -> BENCH_serve.json.

    Two model configs; per quantized linear: dense-dequant matmul vs RSR
    scatter vs Pallas kernel vs Pallas + packed-code streaming, at the decode
    (batch=1) and small-prefill (batch=8) regimes; end-to-end Engine decode
    tokens/s per backend.  On CPU the Pallas rows run the interpreter — a
    functional trajectory number, not TPU perf (the roofline projection for
    TPU is table1_tpu); on a TPU runtime the same harness measures the
    compiled kernel unchanged.  --smoke shrinks shapes/reps for CI.
    """
    import dataclasses
    import json
    import jax
    import jax.numpy as jnp
    from repro.config import ServeConfig, get_config
    from repro.core import (pack_code_words, preprocess_ternary_direct,
                            random_ternary)
    from repro.core.preprocess import code_traffic_bits_per_weight
    from repro.kernels.dispatch import rsr_serve_matmul, select_backend
    from repro.models import transformer as tfm
    from repro.serve.engine import Engine

    reps = 2 if smoke else 5
    result = {
        "meta": {
            "schema": "bench_serve_v1",
            "host_backend": jax.default_backend(),
            "resolved_rsr_backend": select_backend(),
            "smoke": smoke,
            "rsr_k": 5,
            "code_bits_per_weight_packed": code_traffic_bits_per_weight(5),
            "code_bits_per_weight_budget": 2.0,
            "note": ("pallas rows on CPU run the Pallas interpreter "
                     "(functional serve-path trajectory, not TPU perf; "
                     "table1_tpu holds the TPU roofline projection)"),
        },
        "models": {},
    }

    def time_linear(n, m, batch):
        a = random_ternary(jax.random.PRNGKey(n + m), (n, m))
        idx = preprocess_ternary_direct(a, 5)
        packed = pack_code_words(idx.codes)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, n))
        w_dense = a.astype(jnp.bfloat16)
        # every variant jitted end-to-end (all rows measure compiled
        # steady-state latency, not eager padding/dispatch overhead) with
        # the backend pinned per row — labels stay honest even with
        # REPRO_RSR_BACKEND set in the environment
        kb = "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"
        variants = {
            "dense": jax.jit(lambda v, c, p: v.astype(jnp.bfloat16)
                             @ w_dense),
            "scatter": jax.jit(lambda v, c, p: rsr_serve_matmul(
                v, c, k=5, n_out=m, backend="scatter")),
            "pallas": jax.jit(lambda v, c, p: rsr_serve_matmul(
                v, c, k=5, n_out=m, backend=kb)),
            "pallas_packed": jax.jit(lambda v, c, p: rsr_serve_matmul(
                v, c, k=5, packed=p, n_out=m, backend=kb)),
        }
        row = {"shape": [n, m], "batch": batch}
        for vname, fn in variants.items():
            fn(x, idx.codes, packed)[0].block_until_ready()  # compile
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(x, idx.codes, packed).block_until_ready()
            row[f"{vname}_us"] = (time.perf_counter() - t0) / reps * 1e6
        return row

    for name in ("falcon3-3b-1.58bit", "gemma-2b"):
        cfg_full = get_config(name)
        d, ff = cfg_full.d_model, cfg_full.d_ff
        if smoke:
            d, ff = 256, 512
        shapes = [(d, d), (d, ff), (ff, d)]
        per_linear = [time_linear(n, m, b)
                      for (n, m) in shapes for b in ((1,) if smoke
                                                     else (1, 8))]
        for row in per_linear:
            emit(f"serve_linear_{name}_n{row['shape'][0]}m{row['shape'][1]}"
                 f"b{row['batch']}", row["pallas_packed_us"],
                 f"dense_us={row['dense_us']:.0f};"
                 f"scatter_us={row['scatter_us']:.0f};"
                 f"pallas_us={row['pallas_us']:.0f}")

        # end-to-end engine decode at reduced scale (CPU-tractable)
        cfg = dataclasses.replace(cfg_full.reduced(), vocab_size=256,
                                  num_layers=2)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        serve_rsr = tfm.serve_params(params, cfg)
        serve_dense = tfm.serve_params(
            params, dataclasses.replace(cfg, rsr_serve=False))
        scfg = ServeConfig(max_seq_len=64, batch_size=2)
        prompts = jnp.ones((2, 8), jnp.int32)
        engine_rows = {}
        outs = {}
        # the engine rows pin backends via cfg.rsr_backend; the operator env
        # var outranks that (dispatch resolution order), so clear it for the
        # duration or a set REPRO_RSR_BACKEND would silently measure one
        # backend under all three labels
        import os
        env_backend = os.environ.pop("REPRO_RSR_BACKEND", None)
        try:
            for label, tree, backend in (
                    ("dense", serve_dense, "auto"),
                    ("rsr_scatter", serve_rsr, "scatter"),
                    ("rsr_pallas", serve_rsr, "auto")):
                e = Engine(dataclasses.replace(cfg, rsr_backend=backend),
                           tree, scfg)
                outs[label] = e.generate(prompts, 8)        # compile + check
                engine_rows[label] = e.decode_throughput(
                    steps=4 if smoke else 16)
        finally:
            if env_backend is not None:
                os.environ["REPRO_RSR_BACKEND"] = env_backend
        equal = bool(np.array_equal(outs["dense"], outs["rsr_pallas"]) and
                     np.array_equal(outs["dense"], outs["rsr_scatter"]))
        result["models"][name] = {
            "per_linear": per_linear,
            "engine_decode": {
                "batch": scfg.batch_size,
                "reduced_dims": {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                                 "num_layers": cfg.num_layers},
                "outputs_equal_across_backends": equal,
                **{f"{k}_tokens_per_s": round(v["tokens_per_s"], 2)
                   for k, v in engine_rows.items()},
                **{f"{k}_us_per_step": round(v["us_per_step"], 1)
                   for k, v in engine_rows.items()},
            },
        }
        emit(f"serve_engine_{name}",
             engine_rows["rsr_pallas"]["us_per_step"],
             f"tokens_per_s={engine_rows['rsr_pallas']['tokens_per_s']:.1f};"
             f"dense_tokens_per_s="
             f"{engine_rows['dense']['tokens_per_s']:.1f};"
             f"outputs_equal={equal}")
        assert equal, "serve backends must decode identical tokens"

    assert result["meta"]["code_bits_per_weight_packed"] <= 2.0
    _merge_json(json_path, result)       # keep the request_plane section
    return result


def request_plane_bench(json_path: str = "BENCH_serve.json",
                        smoke: bool = False):
    """Request-plane trajectory -> the ``request_plane`` section of
    BENCH_serve.json (``--only request_plane``).

    One constrained paged geometry (pool of 9 blocks; each request's worst
    case is 4, so three concurrent requests oversubscribe it), driven at
    overcommit 1.0 vs 1.5 through ``PriorityScheduler``: preemption /
    shed / re-admission counts, p50/p99 completion latency per priority
    lane, and decode tokens/s.  Token parity of every completed request
    against an unconstrained solo run is a hard assert, as are the two
    deterministic policy outcomes (1.0 never preempts — the budget gate;
    1.5 must preempt at least once — the pool genuinely runs dry) and a
    deliberately expired-deadline request being shed with TIMEOUT.  The
    1.5-vs-1.0 throughput comparison is timing-sensitive and goes through
    the perf gate (warn unless BENCH_STRICT=1).
    """
    import dataclasses
    import jax
    from repro.config import ServeConfig, get_config
    from repro.models import transformer as tfm
    from repro.serve.engine import Engine, Request, RequestStatus
    from repro.serve.frontend import PriorityScheduler

    cfg = dataclasses.replace(
        get_config("falcon3-3b-1.58bit").reduced(), vocab_size=256,
        num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tree = tfm.serve_params(params, cfg)
    n_req = 3 if smoke else 6
    max_new = 20                         # 9 + 20 = 29 tokens -> 4 blocks
    base = ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=9, prefill_chunk=8, paged_attn="gather")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 9).astype(np.int32)
               for _ in range(n_req)]

    def traffic():
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new=max_new,
                        priority=i % 3,
                        deadline_s=120.0 if i % 3 == 0 else None)
                for i in range(n_req)]
        # one deliberately expired request: must be SHED (TIMEOUT terminal
        # state, machine-readable reason), not raise or hang the drain
        reqs.append(Request(rid=99, prompt=prompts[0].copy(), max_new=4,
                            priority=2, deadline_s=0.0))
        return reqs

    ref = Engine(cfg, tree, ServeConfig(max_seq_len=32, batch_size=1))
    want = {}
    for i, p in enumerate(prompts):
        ref.reset()
        want[i] = np.asarray(ref.generate(p[None, :], max_new)[0])

    section = {
        "meta": {"schema": "bench_request_plane_v1", "smoke": smoke,
                 "requests": n_req, "max_new": max_new,
                 "pool_blocks": base.kv_num_blocks,
                 "worst_case_blocks_per_request": 4,
                 "batch": base.batch_size,
                 "reduced_dims": {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                                  "num_layers": cfg.num_layers},
                 "note": ("gather-mode paged engine on the reduced config; "
                          "latencies are CPU wall clock — trajectory "
                          "numbers, not TPU perf")},
        "overcommit": {},
    }
    for oc in (1.0, 1.5):
        eng = Engine(cfg, tree, dataclasses.replace(base, overcommit=oc))
        for _timed in (False, True):     # first pass absorbs compiles
            eng.reset()
            sched = PriorityScheduler(eng)
            for r in traffic():
                sched.submit(r)
            t0 = time.perf_counter()
            done = sched.run()
            dt = time.perf_counter() - t0
        ok = [r for r in done if r.status is RequestStatus.OK]
        shed = [r for r in done if r.status is RequestStatus.TIMEOUT]
        assert len(ok) == n_req, [r.status for r in done]
        assert len(shed) == 1 and shed[0].rid == 99 and not shed[0].generated
        for r in ok:                     # hard: greedy parity vs solo runs
            np.testing.assert_array_equal(np.asarray(r.generated),
                                          want[r.rid])
        st = sched.stats
        if oc > 1.0:
            assert st["preemptions"] >= 1, \
                "1.5x overcommit never exercised preemption"
        else:
            assert st["preemptions"] == 0, \
                "the 1.0x budget gate admitted past the pool"
        assert eng.pool.free_count == eng.pool.num_blocks, "blocks leaked"
        lanes = {}
        for lane in (0, 1, 2):
            lat = sorted(r.completed_at - r.arrival for r in ok
                         if r.priority == lane)
            if lat:
                lanes[str(lane)] = {
                    "n": len(lat),
                    "p50_s": round(float(np.percentile(lat, 50)), 4),
                    "p99_s": round(float(np.percentile(lat, 99)), 4)}
        toks = sum(len(r.generated) for r in ok)
        section["overcommit"][f"{oc:.1f}"] = {
            "tokens_per_s": round(toks / dt, 2),
            "preemptions": st["preemptions"], "shed": st["shed"],
            "timeouts": st["timeouts"],
            "readmissions": st["readmissions"],
            "readmission_hit_tokens": st["readmission_hit_tokens"],
            "lane_latency": lanes, "token_parity": True,
        }
        emit(f"request_plane_oc{oc:.1f}", dt * 1e6,
             f"tokens_per_s={toks / dt:.1f};"
             f"preempt={st['preemptions']};shed={st['shed']};"
             f"readmit={st['readmissions']}")
    r10 = section["overcommit"]["1.0"]
    r15 = section["overcommit"]["1.5"]
    perf_gate(r15["tokens_per_s"] >= r10["tokens_per_s"],
              f"1.5x overcommit slower than 1.0x "
              f"({r15['tokens_per_s']:.1f} vs {r10['tokens_per_s']:.1f} "
              f"tok/s; timing-sensitive; BENCH_STRICT=1 to enforce)",
              section)
    _merge_json(json_path, {"request_plane": section})
    return section


def chaos_bench(json_path: str = "BENCH_serve.json", smoke: bool = False):
    """Chaos soak -> the ``chaos`` section of BENCH_serve.json
    (``--only chaos``).

    Mixed-priority traffic on the constrained paged geometry (pool of 9,
    overcommit 1.5, prefill-token budget 16/tick) driven under a
    randomized-but-deterministic :class:`~repro.serve.faults.FaultPlan`
    (seeded; the printed spec replays via ``REPRO_FAULTS``), with the
    invariant auditor running EVERY tick.  Hard asserts per seed:

    * no wedge — ``run()`` drains (the barren-tick guard would raise);
    * every request reaches a terminal state, and the only non-OK state
      is the deliberately poisoned request's FAILED_NUMERIC quarantine;
    * OK requests decode greedy tokens bitwise-equal to a fault-free
      solo run; the quarantined request's partial output is a bitwise
      PREFIX of its fault-free run;
    * zero block leaks (free count restored, refcounts at zero).

    A second leg simulates a mid-serve crash: snapshot the plane with
    every request inflight, restore onto a FRESH engine, and assert the
    drain resumes all of them with bitwise-continuous greedy tokens and
    warm-hit (tail-only) re-prefill.
    """
    import dataclasses
    import jax
    from repro.config import ServeConfig, get_config
    from repro.models import transformer as tfm
    from repro.serve import audit, faults
    from repro.serve.engine import Engine, Request, RequestStatus
    from repro.serve.frontend import PriorityScheduler

    cfg = dataclasses.replace(
        get_config("falcon3-3b-1.58bit").reduced(), vocab_size=256,
        num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tree = tfm.serve_params(params, cfg)
    n_req = 4 if smoke else 8
    max_new = 12
    seeds = (0,) if smoke else (0, 1)
    base = ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=9, prefill_chunk=8, paged_attn="gather",
                       overcommit=1.5, max_prefill_tokens_per_tick=16,
                       audit_interval=1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 9).astype(np.int32)
               for _ in range(n_req)]
    ref = Engine(cfg, tree, ServeConfig(max_seq_len=32, batch_size=1,
                                        prefill_chunk=8))
    want = {}
    for i, p in enumerate(prompts):                    # the fault-free runs
        ref.reset()
        want[i] = np.asarray(ref.generate(p[None, :], max_new)[0])

    section = {
        "meta": {"schema": "bench_chaos_v1", "smoke": smoke,
                 "requests": n_req, "max_new": max_new,
                 "pool_blocks": base.kv_num_blocks,
                 "overcommit": base.overcommit,
                 "prefill_budget": base.max_prefill_tokens_per_tick,
                 "audit_interval": 1,
                 "note": ("gather-mode paged engine, reduced config; the "
                          "auditor runs every tick, so a green soak also "
                          "proves every invariant held under the chaos")},
        "seeds": {},
    }
    for seed in seeds:
        plan = faults.FaultPlan.random(seed, ticks=32)
        eng = Engine(cfg, tree, base)
        sched = PriorityScheduler(eng, fault_plan=plan)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p.copy(), max_new=max_new,
                                 priority=i % 3))
        t0 = time.perf_counter()
        done = {r.rid: r for r in sched.run()}        # no wedge: it drained
        dt = time.perf_counter() - t0
        assert sorted(done) == list(range(n_req)), "not every request terminal"
        quarantined = [r for r in done.values()
                       if r.status is RequestStatus.FAILED_NUMERIC]
        assert len(quarantined) == plan.fired["poison"] <= 1
        toks = 0
        for r in done.values():
            assert r.status in (RequestStatus.OK,
                                RequestStatus.FAILED_NUMERIC), r.status
            toks += len(r.generated)
            if r.status is RequestStatus.OK:
                assert len(r.generated) == max_new
                np.testing.assert_array_equal(np.asarray(r.generated),
                                              want[r.rid])
            else:                                      # bitwise PREFIX
                np.testing.assert_array_equal(
                    np.asarray(r.generated),
                    want[r.rid][:len(r.generated)])
        assert eng.pool.free_count == eng.pool.num_blocks, "blocks leaked"
        assert eng.pool.live_refs == 0
        audit.audit_scheduler(sched)
        assert sum(plan.fired.values()) >= 2, \
            f"vacuous chaos plan {plan.spec!r}: nothing fired"
        st = sched.stats
        section["seeds"][str(seed)] = {
            "spec": plan.spec, "fired": dict(plan.fired),
            "ok": n_req - len(quarantined), "quarantined": len(quarantined),
            "tokens_per_s": round(toks / dt, 2),
            "preemptions": st["preemptions"],
            "readmissions": st["readmissions"],
            "prefill_faults": st["prefill_faults"], "shed": st["shed"],
            "token_parity": True, "zero_leaks": True,
        }
        emit(f"chaos_seed{seed}", dt * 1e6,
             f"tokens_per_s={toks / dt:.1f};"
             f"fired={sum(plan.fired.values())};"
             f"preempt={st['preemptions']};quarantined={len(quarantined)}")

    # -- crash-safe snapshot/restore leg ------------------------------------
    snap_scfg = dataclasses.replace(base, overcommit=1.0,
                                    max_prefill_tokens_per_tick=0)
    eng = Engine(cfg, tree, snap_scfg)
    sched = PriorityScheduler(eng)
    for i in range(3):                   # 3 x worst-case 3 blocks == pool
        sched.submit(Request(rid=i, prompt=prompts[i].copy(),
                             max_new=max_new))
    finished: list = []
    for _ in range(4 if smoke else 6):   # mid-serve: everyone inflight
        sched.tick(finished)
    assert not finished and all(s is not None for s in sched.slots)
    cut = {r.rid: len(r.generated) for r in sched.slots}
    snap = sched.snapshot()
    eng2 = Engine(cfg, tree, snap_scfg)  # the "crashed" engine is abandoned
    sched2 = PriorityScheduler(eng2)
    sched2.restore(snap)
    t0 = time.perf_counter()
    done = {r.rid: r for r in sched2.run()}
    dt = time.perf_counter() - t0
    assert sorted(done) == [0, 1, 2]
    for rid, r in done.items():
        assert r.status is RequestStatus.OK and len(r.generated) == max_new
        # bitwise-continuous: pre-crash tokens + resumed tokens == solo run
        np.testing.assert_array_equal(np.asarray(r.generated), want[rid])
    assert sched2.stats["restored"] == 3
    assert eng2.pool.stats["hit_tokens"] == 24, \
        "restore re-prefilled the prompt instead of warm-hitting it"
    assert eng2.pool.free_count == eng2.pool.num_blocks
    audit.audit_scheduler(sched2)
    section["snapshot_restore"] = {
        "inflight_at_crash": 3,
        "tokens_at_crash": cut,
        "registered_blocks_exported": len(snap["registered"]),
        "resume_warm_hit_tokens": int(eng2.pool.stats["hit_tokens"]),
        "bitwise_continuous": True,
        "resume_tokens_per_s": round(
            sum(max_new - c for c in cut.values()) / dt, 2),
    }
    emit("chaos_snapshot_restore", dt * 1e6,
         f"restored=3;warm_hit_tokens={eng2.pool.stats['hit_tokens']};"
         f"bitwise_continuous=True")
    _merge_json(json_path, {"chaos": section})
    return section


def durability_bench(json_path: str = "BENCH_serve.json",
                     smoke: bool = False):
    """Durable-serve soak -> the ``durability`` section of BENCH_serve.json
    (``--only durability``).

    Per seed: mixed traffic on the constrained paged geometry with
    on-disk checkpoints every 2 ticks and write-ahead journaling, disk
    faults live (seeded torn/flip/fsync ordinals), KILLED at a seeded
    random tick — the process state is abandoned, only the directory
    survives.  Recovery boots a FRESH engine from disk
    (``durability.recover_scheduler``: newest valid checkpoint +
    journal-tail replay, I1-I8 audited) and drains.  Hard asserts:

    * every request reaches a terminal state, OK everywhere;
    * pre-kill completions are reported verbatim off the journal (same
      exact tokens), survivors' greedy streams are bitwise-equal to a
      fault-free solo run — crash + disk faults changed nothing;
    * zero block leaks on the recovered engine, auditor quiet.

    Reported per seed: recovery latency, journal-replay length,
    checkpoint size, checkpoints skipped.  A final leg truncates the
    newest checkpoint mid-file and asserts recovery degrades to the
    previous one (fallback ladder) instead of raising, still bitwise.
    """
    import dataclasses
    import shutil
    import tempfile
    import jax
    from repro.config import ServeConfig, get_config
    from repro.models import transformer as tfm
    from repro.serve import audit, durability, faults
    from repro.serve.engine import Engine, Request, RequestStatus
    from repro.serve.frontend import PriorityScheduler

    cfg = dataclasses.replace(
        get_config("falcon3-3b-1.58bit").reduced(), vocab_size=256,
        num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tree = tfm.serve_params(params, cfg)
    n_req = 3 if smoke else 6
    max_new = 12
    seeds = (0,) if smoke else (0, 1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 9).astype(np.int32)
               for _ in range(n_req)]
    ref = Engine(cfg, tree, ServeConfig(max_seq_len=32, batch_size=1,
                                        prefill_chunk=8))
    want = {}
    for i, p in enumerate(prompts):                    # the fault-free runs
        ref.reset()
        want[i] = np.asarray(ref.generate(p[None, :], max_new)[0])

    section = {
        "meta": {"schema": "bench_durability_v1", "smoke": smoke,
                 "requests": n_req, "max_new": max_new,
                 "checkpoint_interval": 2, "audit_interval": 1,
                 "note": ("kill-at-random-tick soak with torn/flip/fsync "
                          "disk faults live; recovery = fresh engine + "
                          "newest valid checkpoint + journal-tail replay, "
                          "asserted bitwise against fault-free solo runs")},
        "seeds": {},
    }
    root = tempfile.mkdtemp(prefix="repro-durability-")
    try:
        for seed in seeds:
            srng = np.random.default_rng(seed)
            # ordinals scaled to the write volume (n_req submit appends +
            # a checkpoint temp every 2 ticks) so every seam can land
            # before the kill
            spec = (f"torn@{srng.integers(2, n_req + 1)},"
                    f"flip@{srng.integers(n_req + 1, n_req + 3)},"
                    f"fsync@{srng.integers(2, 6)}")
            kill_tick = int(srng.integers(4, 10))
            cdir = os.path.join(root, f"seed{seed}")
            scfg = ServeConfig(max_seq_len=32, batch_size=3,
                               kv_block_size=8, kv_num_blocks=12,
                               prefill_chunk=8, paged_attn="gather",
                               audit_interval=1, checkpoint_dir=cdir,
                               checkpoint_interval=2)
            plan = faults.FaultPlan.parse(spec)
            eng = Engine(cfg, tree, scfg)
            sched = PriorityScheduler(eng, fault_plan=plan)
            for i, p in enumerate(prompts):
                sched.submit(Request(rid=i, prompt=p.copy(),
                                     max_new=max_new, priority=i % 3))
            finished: list = []
            for _ in range(kill_tick):                 # ... then SIGKILL:
                sched.tick(finished)                   # nothing cleans up
            pre_kill = {r.rid: list(r.generated) for r in finished}
            fired = dict(plan.fired)
            assert sum(fired.values()) >= 1, \
                f"vacuous disk-fault plan {spec!r}: nothing fired"

            eng2 = Engine(cfg, tree, scfg)
            t0 = time.perf_counter()
            sched2, report = durability.recover_scheduler(eng2)
            rec_dt = time.perf_counter() - t0
            got = {}
            for r in report["completed"]:              # journaled verbatim
                assert r.status is RequestStatus.OK
                assert list(r.generated) == pre_kill[r.rid], \
                    "recovery recomputed a journaled terminal"
                got[r.rid] = list(r.generated)
            t0 = time.perf_counter()
            done = sched2.run()
            dt = time.perf_counter() - t0
            toks = 0
            for r in done:
                assert r.status is RequestStatus.OK, (r.rid, r.status)
                toks += len(r.generated)
                got[r.rid] = list(r.generated)
            # a request is lost ONLY when the faults destroyed its every
            # durable record (torn submit append + no covering checkpoint)
            # — durability cannot resurrect data that never hit disk.
            # Every SURVIVOR must be bitwise-identical to the fault-free
            # solo run (the ISSUE's continuity bar).
            lost = sorted(set(range(n_req)) - set(got))
            assert got, "recovery lost every request"
            if lost:
                assert (fired["torn"] + fired["flip"]
                        + fired["fsync"]) >= 1, \
                    f"requests {lost} lost without any disk fault"
            for i in sorted(got):                      # bitwise continuity
                np.testing.assert_array_equal(np.asarray(got[i]), want[i])
            assert eng2.pool.free_count == eng2.pool.num_blocks, "leaked"
            assert eng2.pool.live_refs == 0
            audit.audit_scheduler(sched2)
            st = sched2._ckpt_store
            ckpt_bytes = os.path.getsize(
                st._ckpt_path(st.list_checkpoints()[-1]))
            section["seeds"][str(seed)] = {
                "spec": spec, "kill_tick": kill_tick, "fired": fired,
                "completed_pre_kill": len(pre_kill),
                "checkpoint_seq": report["checkpoint_seq"],
                "checkpoints_skipped": report["checkpoints_skipped"],
                "journal_replay_events": report["journal_events"],
                "journal_truncated": report["journal_truncated"],
                "requeued": report["requeued"],
                "resumed_inflight": report["resumed_inflight"],
                "lost_to_faulted_writes": lost,
                "recovery_latency_ms": round(rec_dt * 1e3, 2),
                "checkpoint_bytes": ckpt_bytes,
                "drain_tokens_per_s": round(toks / dt, 2),
                "token_parity": True, "zero_leaks": True,
            }
            emit(f"durability_seed{seed}", rec_dt * 1e6,
                 f"replayed={report['journal_events']};"
                 f"skipped={report['checkpoints_skipped']};"
                 f"ckpt_bytes={ckpt_bytes};"
                 f"fired={sum(fired.values())}")

        # -- corrupted-newest-checkpoint fallback leg -----------------------
        cdir = os.path.join(root, "fallback")
        scfg = ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                           kv_num_blocks=12, prefill_chunk=8,
                           paged_attn="gather", audit_interval=1,
                           checkpoint_dir=cdir, checkpoint_interval=2)
        eng = Engine(cfg, tree, scfg)
        sched = PriorityScheduler(eng)
        for i in range(3):
            sched.submit(Request(rid=i, prompt=prompts[i].copy(),
                                 max_new=max_new))
        finished = []
        for _ in range(6):
            sched.tick(finished)
        st = sched._ckpt_store
        seqs = st.list_checkpoints()
        assert len(seqs) >= 2, "fallback leg needs two checkpoints"
        path = st._ckpt_path(seqs[-1])
        with open(path, "rb") as f:
            data = f.read()
        with open(path, "wb") as f:                    # torn newest
            f.write(data[:len(data) // 2])
        eng2 = Engine(cfg, tree, scfg)
        t0 = time.perf_counter()
        sched2, report = durability.recover_scheduler(eng2)
        rec_dt = time.perf_counter() - t0
        assert report["checkpoints_skipped"] == 1, "fallback did not engage"
        assert report["checkpoint_seq"] == seqs[-2]
        got = {r.rid: list(r.generated) for r in report["completed"]}
        for r in sched2.run():
            got[r.rid] = list(r.generated)
        for i in range(3):
            np.testing.assert_array_equal(np.asarray(got[i]), want[i])
        assert eng2.pool.free_count == eng2.pool.num_blocks
        section["fallback"] = {
            "corrupted_seq": seqs[-1], "restored_seq": seqs[-2],
            "checkpoints_skipped": 1,
            "recovery_latency_ms": round(rec_dt * 1e3, 2),
            "token_parity": True,
        }
        emit("durability_fallback", rec_dt * 1e6,
             f"skipped=1;restored_seq={seqs[-2]};token_parity=True")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    _merge_json(json_path, {"durability": section})
    return section


def telemetry_bench(json_path: str = "BENCH_serve.json",
                    smoke: bool = False):
    """Observability plane -> the ``telemetry`` section of
    BENCH_serve.json (``--only telemetry``).

    Identical mixed-priority traffic on the constrained paged geometry,
    run twice: telemetry disabled (the default) and enabled.  Hard
    asserts: every request OK in both modes and bitwise token parity
    ACROSS modes (observing the plane must not change a single token),
    plus a well-formed canonical trace export with per-lane
    queue/prefill/decode latency attribution on the enabled leg.  Perf
    gates (warn unless BENCH_STRICT=1): the disabled run is not slower
    than the enabled one beyond scheduler noise, and the disabled-mode
    registry no-op costs under 2 µs per call.
    """
    import dataclasses
    import json as _json
    import jax
    from repro.config import ServeConfig, get_config
    from repro.models import transformer as tfm
    from repro.serve import telemetry as tele
    from repro.serve.engine import Engine, Request, RequestStatus
    from repro.serve.frontend import PriorityScheduler

    cfg = dataclasses.replace(
        get_config("falcon3-3b-1.58bit").reduced(), vocab_size=256,
        num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tree = tfm.serve_params(params, cfg)
    n_req = 3 if smoke else 6
    max_new = 20
    base = ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=9, prefill_chunk=8, paged_attn="gather")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 9).astype(np.int32)
               for _ in range(n_req)]

    def traffic():
        return [Request(rid=i, prompt=prompts[i].copy(), max_new=max_new,
                        priority=i % 3) for i in range(n_req)]

    section = {
        "meta": {"schema": "bench_telemetry_v1", "smoke": smoke,
                 "requests": n_req, "max_new": max_new,
                 "batch": base.batch_size,
                 "pool_blocks": base.kv_num_blocks,
                 "note": ("gather-mode paged engine on the reduced config; "
                          "latencies are CPU wall clock — trajectory "
                          "numbers, not TPU perf")},
    }
    runs = {}
    for mode in (False, True):
        eng = Engine(cfg, tree, dataclasses.replace(base, telemetry=mode))
        for _timed in (False, True):     # first pass absorbs compiles
            eng.reset()
            eng.telemetry.trace.clear()
            sched = PriorityScheduler(eng)
            for r in traffic():
                sched.submit(r)
            t0 = time.perf_counter()
            done = sched.run()
            dt = time.perf_counter() - t0
        assert all(r.status is RequestStatus.OK for r in done), \
            [r.status for r in done]
        runs[mode] = {
            "dt": dt, "eng": eng, "sched": sched,
            "toks": sum(len(r.generated) for r in done),
            "tokens": {r.rid: list(r.generated) for r in done}}
    # hard: observing the plane must not change a single decoded token
    assert runs[False]["tokens"] == runs[True]["tokens"], \
        "telemetry changed decode tokens"

    # enabled leg: trace + attribution are the introspection payload
    tel = runs[True]["eng"].telemetry
    ev = tel.trace.events
    assert ev, "enabled run produced no trace events"
    doc = _json.loads(tel.dump_trace())
    assert doc["schema"] == "repro_trace_v1" and doc["events"]
    att = tele.latency_attribution(ev)
    assert att and all(att[lane]["decode"]["n"] >= 1 for lane in att), \
        "latency attribution missing decode stage"
    text = tel.render_prometheus()
    assert "serve_tick_phase_seconds" in text, "phase profile missing"
    lanes = {str(lane): {stage: {"n": s["n"],
                                 "mean_s": round(s["mean"], 6),
                                 "p50_s": round(s["p50"], 6),
                                 "p99_s": round(s["p99"], 6)}
                         for stage, s in stages.items()}
             for lane, stages in att.items()}

    # disabled-mode no-op overhead: the whole call chain on a disabled
    # registry (get -> NULL -> observe) per op
    noop = tele.Telemetry(enabled=False)
    n_ops = 20_000 if smoke else 200_000
    t0 = time.perf_counter()
    for _ in range(n_ops):
        noop.histogram("serve_noop_probe").observe(1.0)
    per_op_us = (time.perf_counter() - t0) / n_ops * 1e6

    dt_off, dt_on = runs[False]["dt"], runs[True]["dt"]
    section["disabled"] = {
        "wall_s": round(dt_off, 4),
        "tokens_per_s": round(runs[False]["toks"] / dt_off, 2)}
    section["enabled"] = {
        "wall_s": round(dt_on, 4),
        "tokens_per_s": round(runs[True]["toks"] / dt_on, 2),
        "trace_events": len(ev), "lane_latency": lanes,
        "token_parity_vs_disabled": True}
    section["noop_overhead_us_per_call"] = round(per_op_us, 4)
    section["enabled_over_disabled_wall_ratio"] = round(dt_on / dt_off, 4)

    perf_gate(dt_off <= dt_on * 1.10,
              f"telemetry-off run slower than telemetry-on "
              f"({dt_off:.3f}s vs {dt_on:.3f}s; timing-sensitive; "
              f"BENCH_STRICT=1 to enforce)", section)
    perf_gate(per_op_us < 2.0,
              f"disabled-mode no-op costs {per_op_us:.2f}us/call "
              f"(want < 2us; timing-sensitive)", section)
    emit("telemetry_disabled", dt_off * 1e6,
         f"tokens_per_s={runs[False]['toks'] / dt_off:.1f}")
    emit("telemetry_enabled", dt_on * 1e6,
         f"tokens_per_s={runs[True]['toks'] / dt_on:.1f};"
         f"trace_events={len(ev)}")
    emit("telemetry_noop", per_op_us, "us_per_disabled_registry_call")
    _merge_json(json_path, {"telemetry": section})
    return section


def prefill_bench(json_path: str = "BENCH_prefill.json", smoke: bool = False):
    """Prefill-path trajectory benchmark -> BENCH_prefill.json.

    Three sections:

    * ``kernel``: one quantized linear at true falcon3-3b layer shapes as
      the flattened row count grows 1 -> 256 (decode -> prefill tile
      regime) — the per-row amortization the chunked engine path buys.
    * ``engine``: chunked prefill vs the old decode-step-scan reference at
      reduced model scale, per backend: tokens/s for several chunk sizes,
      with last-position logits AND the full KV cache asserted identical
      to the scan path.
    * ``scheduler``: continuous-batching throughput over mixed-length
      prefill+decode traffic, with every request's tokens asserted equal
      to per-request generation (the left-padding regression).

    On CPU the kernel rows run the Pallas interpreter (functional
    trajectory, not TPU perf); on a TPU runtime the same harness measures
    the compiled kernel unchanged.  --smoke shrinks shapes/reps for CI.
    """
    import dataclasses
    import json
    import os
    import jax
    import jax.numpy as jnp
    from repro.config import ServeConfig, get_config
    from repro.core import (pack_code_words, preprocess_ternary_direct,
                            random_ternary)
    from repro.kernels.dispatch import (rsr_serve_matmul, select_backend,
                                        select_tiles)
    from repro.models import transformer as tfm
    from repro.serve.engine import BatchScheduler, Engine, Request

    reps = 2 if smoke else 5
    S = 16 if smoke else 64
    chunks = (4, S) if smoke else (8, 32, S)
    result = {
        "meta": {
            "schema": "bench_prefill_v1",
            "host_backend": jax.default_backend(),
            "resolved_rsr_backend": select_backend(),
            "smoke": smoke,
            "seq_len": S,
            "note": ("pallas rows on CPU run the Pallas interpreter "
                     "(functional prefill-path trajectory, not TPU perf)"),
        },
    }

    # ---- kernel: row-count amortization at true layer shapes -------------
    kb = "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"
    d, ff = (256, 512) if smoke else (3072, 9216)     # falcon3-3b layers
    row_counts = (1, 32) if smoke else (1, 64, 256)
    kernel_rows = []
    for (n, m) in ((d, d), (d, ff)):
        a = random_ternary(jax.random.PRNGKey(n + m), (n, m))
        idx = preprocess_ternary_direct(a, 5)
        packed = pack_code_words(idx.codes)
        nb = idx.codes.shape[0]
        entry = {"shape": [n, m], "rows": {}}
        for rows in row_counts:
            x = jax.random.normal(jax.random.PRNGKey(1), (rows, n))
            fn = jax.jit(lambda v: rsr_serve_matmul(
                v, idx.codes, k=5, packed=packed, n_out=m, backend=kb))
            fn(x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(x).block_until_ready()
            us = (time.perf_counter() - t0) / reps * 1e6
            entry["rows"][str(rows)] = {
                "us": us, "us_per_row": us / rows,
                "tiles": list(select_tiles(rows, nb, n))}
        r1 = entry["rows"][str(row_counts[0])]["us_per_row"]
        rN = entry["rows"][str(row_counts[-1])]["us_per_row"]
        emit(f"prefill_linear_n{n}m{m}_rows{row_counts[-1]}",
             entry["rows"][str(row_counts[-1])]["us"],
             f"us_per_row={rN:.1f};amortization={r1/rN:.2f}x")
        kernel_rows.append(entry)
    result["kernel"] = kernel_rows

    # ---- engine: chunked prefill vs the decode-step-scan reference -------
    cfg_base = dataclasses.replace(
        get_config("falcon3-3b-1.58bit").reduced(), vocab_size=256,
        num_layers=2)
    params = tfm.init_params(cfg_base, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_seq_len=S + 32, batch_size=2)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0,
                                 cfg_base.vocab_size)
    # pin backends via cfg (clear the operator env var for the duration —
    # same labeling honesty rationale as serve_bench)
    env_backend = os.environ.pop("REPRO_RSR_BACKEND", None)
    engine_rows = {}
    improved_backends = []
    try:
        for label, backend in (("pallas", "auto"), ("scatter", "scatter")):
            cfg = dataclasses.replace(cfg_base, rsr_backend=backend)
            eng = Engine(cfg, tfm.serve_params(params, cfg), scfg)
            c0 = tfm.init_cache(cfg, 2, scfg.max_seq_len)

            def timed(fn):
                eng.cache = c0
                jax.block_until_ready(fn())            # compile, synced
                t0 = time.perf_counter()
                for _ in range(reps):
                    eng.cache = c0
                    jax.block_until_ready(fn())
                return (time.perf_counter() - t0) / reps

            dt_scan = timed(lambda: eng.prefill_scan(prompts))
            eng.cache = c0
            ref_logits = np.asarray(eng.prefill_scan(prompts))
            ref_cache = eng.cache
            row = {"scan_tokens_per_s": 2 * S / dt_scan,
                   "scan_us": dt_scan * 1e6, "chunked": {}}
            for chunk in chunks:
                # start=0 (cache reset each rep): no per-call device sync
                # inside the timed region — keeps the scan comparison fair
                dt = timed(lambda: eng.prefill(prompts, chunk=chunk,
                                               start=0))
                eng.cache = c0
                logits = np.asarray(eng.prefill(prompts, chunk=chunk,
                                                start=0))
                # tight-allclose + greedy-token equality (bitwise identity
                # is asserted in the suite on shapes where XLA's dot
                # lowering is row-count-invariant; these reduced dims are
                # not — reductions reassociate at ~1e-6)
                parity = bool(
                    np.allclose(logits, ref_logits, rtol=1e-5, atol=1e-5)
                    and np.array_equal(logits.argmax(-1),
                                       ref_logits.argmax(-1))
                    and all(
                        np.allclose(np.asarray(x, np.float32),
                                    np.asarray(y, np.float32),
                                    rtol=1e-5, atol=1e-5)
                        for x, y in zip(jax.tree.leaves(ref_cache),
                                        jax.tree.leaves(eng.cache))))
                assert parity, (label, chunk,
                                "chunked prefill diverged from scan")
                row["chunked"][str(chunk)] = {
                    "tokens_per_s": 2 * S / dt, "us": dt * 1e6,
                    "speedup_vs_scan": dt_scan / dt, "parity": parity}
            best = max(v["speedup_vs_scan"] for v in row["chunked"].values())
            row["best_speedup_vs_scan"] = best
            if best > 1.0:
                improved_backends.append(label)
            engine_rows[label] = row
            emit(f"prefill_engine_{label}_S{S}",
                 min(v["us"] for v in row["chunked"].values()),
                 f"scan_us={dt_scan*1e6:.0f};speedup={best:.2f}x;"
                 f"parity=True")
    finally:
        if env_backend is not None:
            os.environ["REPRO_RSR_BACKEND"] = env_backend
    result["engine"] = {"seq_len": S, "batch": 2,
                        "reduced_dims": {"d_model": cfg_base.d_model,
                                         "d_ff": cfg_base.d_ff,
                                         "num_layers": cfg_base.num_layers},
                        **engine_rows}
    if S >= 64:
        perf_gate(bool(improved_backends),
                  "chunked prefill did not beat the scan path on any "
                  "backend (timing-sensitive; BENCH_STRICT=1 to enforce)",
                  result)

    # ---- scheduler: mixed prefill+decode continuous batching -------------
    cfg = cfg_base
    tree = tfm.serve_params(params, cfg)
    max_new = 4 if smoke else 8
    eng = Engine(cfg, tree, dataclasses.replace(scfg, prefill_chunk=8))
    rng = np.random.default_rng(0)
    lengths = [3, S // 2, 9, S, 5, 12][: 4 if smoke else 6]
    prompts_mixed = [rng.integers(1, cfg.vocab_size, ln).astype(np.int32)
                     for ln in lengths]
    for timed_run in (False, True):         # first pass absorbs compiles
        sched = BatchScheduler(eng)
        for i, p in enumerate(prompts_mixed):
            sched.submit(Request(rid=i, prompt=p, max_new=max_new))
        t0 = time.perf_counter()
        done = sched.run()
        dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done) + sum(lengths)
    ref = Engine(cfg, tree, dataclasses.replace(
        scfg, batch_size=1, prefill_chunk=8))
    equal = True
    for r in done:
        ref.reset()
        want = ref.generate(jnp.asarray(r.prompt)[None, :], r.max_new)[0]
        equal &= bool(np.array_equal(np.asarray(r.generated), want))
    assert equal, "scheduler tokens must equal per-request generation"
    result["scheduler"] = {
        "requests": len(done), "prompt_lengths": lengths,
        "max_new": max_new,
        "tokens_per_s_incl_prefill": total / dt,
        "per_request_token_equality": equal,
    }
    emit(f"prefill_scheduler_{len(done)}req", dt * 1e6,
         f"tokens_per_s={total/dt:.1f};per_request_equal={equal}")

    _merge_json(json_path, result)
    return result


def _merge_json(json_path: str, result: dict):
    """Write `result` to json_path, preserving any top-level key of an
    existing file that `result` doesn't provide (prefill/paged/paged_attn
    co-own BENCH_prefill.json; serve and request_plane co-own
    BENCH_serve.json; any can run alone without clobbering the others'
    sections)."""
    import json
    import os
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                old = json.load(f)
            for k, v in old.items():
                result.setdefault(k, v)
        except (OSError, ValueError):
            pass
    with open(json_path, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"wrote {json_path}", flush=True)


def paged_bench(json_path: str = "BENCH_prefill.json", smoke: bool = False):
    """Paged-KV trajectory benchmark -> the ``paged`` section of
    BENCH_prefill.json (``--only paged``).

    Three subsections, all on the reduced serve config (CPU-tractable;
    the same harness measures the compiled kernels unchanged on TPU):

    * ``parity``: block-paged generate must equal dense generate
      token-for-token (asserted; the bitwise bar lives in tests).
    * ``shared_prefix``: continuous-batching traffic where every request
      shares a long prompt prefix — paged admissions hash-hit the resident
      prefix blocks and prefill only the tail, so scheduler tokens/s must
      be >= the dense layout re-prefilling the prefix per request
      (asserted at full size); the admission hit-rate comes from the
      allocator's counters.
    * ``equal_memory``: at the SAME total KV token budget (pool tokens ==
      dense batch * max_seq), mixed traffic whose per-request
      prompt+max_new exceeds the dense per-slot row — the dense scheduler
      must reject every request at submit() while the paged engine admits
      and completes them by pooling blocks across slots (and deduping the
      shared prefix).
    """
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.config import ServeConfig, get_config
    from repro.models import transformer as tfm
    from repro.serve.engine import BatchScheduler, Engine, Request

    cfg = dataclasses.replace(
        get_config("falcon3-3b-1.58bit").reduced(), vocab_size=256,
        num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tree = tfm.serve_params(params, cfg)
    B = 2
    blk = 8
    max_seq = 48 if smoke else 96
    scfg_dense = ServeConfig(max_seq_len=max_seq, batch_size=B,
                             prefill_chunk=8)
    scfg_paged = dataclasses.replace(scfg_dense, kv_block_size=blk)
    rng = np.random.default_rng(0)

    # ---- parity: paged generate == dense generate ------------------------
    e_d = Engine(cfg, tree, scfg_dense)
    e_p = Engine(cfg, tree, scfg_paged)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, 9)),
                          jnp.int32)
    toks_equal = bool(np.array_equal(e_d.generate(prompts, 8),
                                     e_p.generate(prompts, 8)))
    assert toks_equal, "paged generate diverged from dense"
    emit("paged_parity", 0.0, f"tokens_equal={toks_equal}")

    # ---- shared-prefix traffic: hit-rate + tokens/s vs dense re-prefill --
    n_req = 4 if smoke else 8
    prefix_len = 24 if smoke else 64
    tail_len, max_new = 3, 4 if smoke else 8
    prefix = rng.integers(1, cfg.vocab_size, prefix_len).astype(np.int32)

    def traffic():
        # staggered max_new: simultaneous evictions would briefly drain the
        # pool and evict the prefix registration with it (sharing is
        # resident-only; the LRU free-block cache is a ROADMAP follow-on),
        # and real traffic doesn't finish in lockstep anyway
        return [Request(rid=i, prompt=np.concatenate(
                    [prefix, rng2.integers(1, cfg.vocab_size,
                                           tail_len).astype(np.int32)]),
                        max_new=max_new + 2 * (i % 3))
                for i in range(n_req)]

    row = {}
    for label, scfg in (("dense", scfg_dense), ("paged", scfg_paged)):
        eng = Engine(cfg, tree, scfg)
        for timed in (False, True):         # first pass absorbs compiles
            rng2 = np.random.default_rng(1)
            eng.reset()
            sched = BatchScheduler(eng)
            for r in traffic():
                sched.submit(r)
            t0 = time.perf_counter()
            done = sched.run()
            dt = time.perf_counter() - t0
        assert len(done) == n_req and not any(r.error for r in done)
        total = sum(len(r.prompt) + len(r.generated) for r in done)
        row[label] = {"tokens_per_s": total / dt, "us": dt * 1e6}
        if label == "paged":
            st = eng.pool.stats
            row["admission_hit_rate"] = (st["hit_tokens"] /
                                         max(1, st["lookup_tokens"]))
            row["hit_tokens"] = st["hit_tokens"]
            row["cow_copies"] = st["cow_copies"]
            assert eng.pool.free_count == eng.pool.num_blocks, \
                "blocks leaked after a full scheduler run"
    row["speedup_vs_dense"] = (row["paged"]["tokens_per_s"] /
                               row["dense"]["tokens_per_s"])
    if not smoke:
        assert row["admission_hit_rate"] > 0.5, row   # deterministic: hard
        perf_gate(row["speedup_vs_dense"] >= 1.0,
                  f"prefix-hit admissions slower than dense re-prefill "
                  f"(speedup={row['speedup_vs_dense']:.2f}x; timing-"
                  f"sensitive; BENCH_STRICT=1 to enforce)", row)
    emit(f"paged_shared_prefix_{n_req}req", row["paged"]["us"],
         f"dense_us={row['dense']['us']:.0f};"
         f"speedup={row['speedup_vs_dense']:.2f}x;"
         f"hit_rate={row['admission_hit_rate']:.2f}")

    # ---- equal-memory mixed traffic the dense layout cannot admit --------
    # pool budget: num_blocks * blk KV tokens total == dense B * max_seq'
    num_blocks = 6 if smoke else 12
    dense_seq = num_blocks * blk // B           # equal-memory dense rows
    need = (dense_seq + blk) + max_new          # per-request demand
    scfg_small_dense = dataclasses.replace(scfg_dense, max_seq_len=dense_seq)
    scfg_pool = dataclasses.replace(
        scfg_paged, kv_num_blocks=num_blocks)
    shared = rng.integers(1, cfg.vocab_size,
                          dense_seq - max_new).astype(np.int32)

    def mixed():
        return [Request(rid=i, prompt=np.concatenate(
                    [shared, rng3.integers(1, cfg.vocab_size,
                                           need - max_new - len(shared))
                     .astype(np.int32)]), max_new=max_new)
                for i in range(B)]

    rng3 = np.random.default_rng(2)
    e_small = Engine(cfg, tree, scfg_small_dense)
    sd = BatchScheduler(e_small)
    for r in mixed():
        sd.submit(r)
    dense_done = sd.run()
    dense_rejected = sum(1 for r in dense_done if r.error)
    assert dense_rejected == B, \
        "equal-memory dense layout must reject the mixed traffic"

    rng3 = np.random.default_rng(2)
    e_pool = Engine(cfg, tree, scfg_pool)
    sp_ = BatchScheduler(e_pool)
    for r in mixed():
        sp_.submit(r)
    paged_done = sp_.run()
    paged_ok = sum(1 for r in paged_done
                   if not r.error and len(r.generated) == max_new)
    assert paged_ok == B, "paged engine must admit and complete the traffic"
    mem = {
        "kv_token_budget": num_blocks * blk,
        "dense_max_seq_equivalent": dense_seq,
        "request_prompt_plus_max_new": need,
        "dense_rejected": dense_rejected,
        "paged_completed": paged_ok,
        "paged_hit_tokens": e_pool.pool.stats["hit_tokens"],
    }
    emit(f"paged_equal_memory_{B}req", 0.0,
         f"dense_rejected={dense_rejected};paged_completed={paged_ok};"
         f"budget_tokens={num_blocks * blk};need={need}>{dense_seq}")

    result = {"paged": {
        "meta": {"schema": "bench_paged_v1", "smoke": smoke,
                 "kv_block_size": blk, "batch": B, "max_seq_len": max_seq,
                 "reduced_dims": {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                                  "num_layers": cfg.num_layers},
                 "note": ("CPU runs the Pallas interpreter: functional "
                          "trajectory numbers, not TPU perf")},
        "parity_tokens_equal": toks_equal,
        "shared_prefix": row,
        "equal_memory": mem,
    }}
    _merge_json(json_path, result)
    return result


def paged_attn_bench(json_path: str = "BENCH_prefill.json",
                     smoke: bool = False):
    """In-place paged-attention trajectory -> the ``paged_attn`` section of
    BENCH_prefill.json (``--only paged_attn``).

    Three subsections:

    * ``bytes_per_step``: per-decode-step KV bytes at true serve
      geometries (full model dims, not reduced), each side derived from
      ITS OWN implementation — the gather side from ``jax.eval_shape``
      over the real gather expressions (``_gather_blocks`` / the MLA
      ``pool[table].reshape``): read the pool blocks + write the dense
      view + the score/PV einsums read it back = 3 passes over the
      materialized shape; the kernel side from the kernel wrapper's
      actual launch arithmetic (grid = B x query-tiles x MB, one
      (KVH, bs, hd) K and V block DMA per step — ``select_attn_tiles``
      decides the query-tile count, so a regression that re-streams KV
      per query tile shows up here).  Asserted (hard): kernel bytes
      strictly below the gather path at every S >= 256.  Geometry, not
      wall clock, so it holds on any host.
    * ``parity``: kernel-vs-gather greedy decode token equality on the
      reduced serve config (hard assert — the ISSUE acceptance bar).
    * ``decode``: measured engine decode tokens/s per paged backend plus
      the dense layout.  On CPU both paged backends run interpreted
      (functional trajectory, not TPU perf; the kernel pays interpreter
      overhead per layer) — recorded, not gated.
    """
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.config import ServeConfig, get_config
    from repro.kernels.paged_attention import select_attn_tiles
    from repro.models import transformer as tfm
    from repro.models.attention import _gather_blocks
    from repro.serve.engine import Engine
    from repro.serve.paging import paged_layout

    # ---- per-decode-step KV bytes, each side from its own implementation -
    def step_bytes(cfg, S, blk, C=1, batch=1):
        """(kernel_bytes, gather_bytes) for one layer's C-token step."""
        dt = jnp.dtype(cfg.dtype)
        mb = -(-S // blk)
        nb = batch * mb                              # pool covering S
        table = jax.ShapeDtypeStruct((batch, mb), jnp.int32)
        nc = -(-C // select_attn_tiles(C))           # kernel query tiles
        if cfg.attention == "mla":
            pools = [
                jax.ShapeDtypeStruct((nb + 1, blk, cfg.kv_lora_rank), dt),
                jax.ShapeDtypeStruct((nb + 1, blk, cfg.qk_rope_head_dim),
                                     dt)]
            # the gather views the MLA paged branch materializes
            views = [jax.eval_shape(
                lambda p, t, w=p.shape[-1]: p[t].reshape(batch, -1, w),
                p, table) for p in pools]
        else:
            hd = cfg.resolved_head_dim
            pools = [jax.ShapeDtypeStruct((nb + 1, cfg.num_kv_heads, blk,
                                           hd), dt)] * 2      # k and v
            views = [jax.eval_shape(_gather_blocks, p, table)
                     for p in pools]
        # kernel: grid (batch, nc, mb), one pool-block DMA per operand per
        # step — matches the BlockSpec geometry in paged_attention.py
        blk_bytes = sum(int(np.prod(p.shape[1:])) * dt.itemsize
                        for p in pools)
        kernel_b = batch * nc * mb * blk_bytes
        # gather: read the addressed pool blocks + write the dense view +
        # the score/PV einsums read it back
        view_bytes = sum(int(np.prod(v.shape)) * dt.itemsize
                         for v in views)
        gather_b = 3 * view_bytes
        return kernel_b, gather_b

    blk = 16
    seqs = (256, 1024) if smoke else (256, 1024, 4096)
    bytes_rows = []
    for name in ("gemma-2b", "deepseek-v2-lite-16b"):
        fcfg = get_config(name)
        for S in seqs:
            kernel_b, gather_b = step_bytes(fcfg, S, blk)
            assert kernel_b < gather_b, (name, S, kernel_b, gather_b)
            bytes_rows.append({
                "model": name, "seq_len": S, "kv_block_size": blk,
                "kernel_bytes_per_step": kernel_b,
                "gather_bytes_per_step": gather_b,
                "ratio": gather_b / kernel_b,
            })
            emit(f"paged_attn_bytes_{name}_S{S}", 0.0,
                 f"kernel_B={kernel_b};gather_B={gather_b};"
                 f"ratio={gather_b / kernel_b:.1f}x")

    # ---- reduced-config engines: parity + measured decode ----------------
    cfg = dataclasses.replace(
        get_config("falcon3-3b-1.58bit").reduced(), vocab_size=256,
        num_layers=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tree = tfm.serve_params(params, cfg)
    B = 2
    scfg = ServeConfig(max_seq_len=48 if smoke else 96, batch_size=B,
                       prefill_chunk=8, kv_block_size=8)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, 9)), jnp.int32)
    max_new = 6 if smoke else 12
    engines = {
        "kernel": Engine(cfg, tree,
                         dataclasses.replace(scfg, paged_attn="kernel")),
        "gather": Engine(cfg, tree,
                         dataclasses.replace(scfg, paged_attn="gather")),
        "dense": Engine(cfg, tree,
                        dataclasses.replace(scfg, kv_block_size=0)),
    }
    toks = {k: e.generate(prompts, max_new) for k, e in engines.items()}
    parity = bool(np.array_equal(toks["kernel"], toks["gather"]) and
                  np.array_equal(toks["kernel"], toks["dense"]))
    assert parity, "paged-attn kernel decode diverged from the gather path"
    emit("paged_attn_parity", 0.0, f"tokens_equal={parity}")

    decode = {}
    steps = 4 if smoke else 16
    for label, e in engines.items():
        e.reset()
        e.prefill(prompts, start=0)
        decode[label] = e.decode_throughput(steps=steps)
    pool_geom = paged_layout(cfg, scfg)
    emit("paged_attn_decode", decode["kernel"]["us_per_step"],
         f"kernel_tok_s={decode['kernel']['tokens_per_s']:.1f};"
         f"gather_tok_s={decode['gather']['tokens_per_s']:.1f};"
         f"dense_tok_s={decode['dense']['tokens_per_s']:.1f}")

    result = {"paged_attn": {
        "meta": {"schema": "bench_paged_attn_v1", "smoke": smoke,
                 "host_backend": jax.default_backend(),
                 "batch": B, "kv_block_size": scfg.kv_block_size,
                 "pool_blocks": pool_geom.num_blocks,
                 "reduced_dims": {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                                  "num_layers": cfg.num_layers},
                 "note": ("bytes_per_step is exact geometry at FULL model "
                          "dims; decode tokens/s on CPU runs interpreted "
                          "Pallas (functional trajectory — the kernel's "
                          "HBM win needs compiled TPU)")},
        "bytes_per_step": bytes_rows,
        "parity_tokens_equal": parity,
        "decode": {k: {"tokens_per_s": round(v["tokens_per_s"], 2),
                       "us_per_step": round(v["us_per_step"], 1)}
                   for k, v in decode.items()},
    }}
    _merge_json(json_path, result)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true",
                    help="paper-scale n (2^11..2^15); slow on 1 core")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small shapes / few reps for serve_bench")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="serve_bench output path")
    ap.add_argument("--prefill-json", default="BENCH_prefill.json",
                    help="prefill_bench output path")
    args = ap.parse_args()
    ns = [2 ** e for e in ((11, 12, 13, 14, 15) if args.large
                           else (9, 10, 11, 12))]
    print("name,us_per_call,derived")
    tables = {
        "fig4": lambda: fig4_native(ns),
        "fig5": lambda: fig5_memory(ns),
        "fig9": lambda: fig9_opt_k(ns[:2]),
        "fig10": lambda: fig10_pp(ns),
        "fig11": lambda: fig11_numpy(ns),
        "fig6": fig6_llm,
        "table1": table1_tpu,
        "engine": engine_e2e,
        "serve": lambda: serve_bench(args.json, smoke=args.smoke),
        "request_plane": lambda: request_plane_bench(args.json,
                                                     smoke=args.smoke),
        "chaos": lambda: chaos_bench(args.json, smoke=args.smoke),
        "durability": lambda: durability_bench(args.json,
                                               smoke=args.smoke),
        "telemetry": lambda: telemetry_bench(args.json, smoke=args.smoke),
        "prefill": lambda: prefill_bench(args.prefill_json,
                                         smoke=args.smoke),
        "paged": lambda: paged_bench(args.prefill_json, smoke=args.smoke),
        "paged_attn": lambda: paged_attn_bench(args.prefill_json,
                                               smoke=args.smoke),
    }
    for name, fn in tables.items():
        # an exact table name selects only that table ("--only paged" must
        # not also run paged_attn); anything else remains a substring match
        if args.only and args.only != name and (
                args.only in tables or args.only not in name):
            continue
        fn()
    if PERF_WARNINGS:
        print(f"{len(PERF_WARNINGS)} perf gate(s) warned "
              f"(BENCH_STRICT=1 to enforce)", flush=True)


if __name__ == "__main__":
    main()
