"""Vectorized NumPy implementations of RSR / RSR++ used by the benchmark
tables (the paper's §5.1/§5.2 environment is scalar C++ / NumPy — this is
the faithful CPU-algorithm comparison, independent of JAX/XLA)."""
from __future__ import annotations

import numpy as np


def preprocess_np(b: np.ndarray, k: int):
    """Algorithm 1 -> (perm (nb,n), seg (nb,2^k+1)) int32."""
    n, m = b.shape
    pad = (-m) % k
    if pad:
        b = np.pad(b, ((0, 0), (0, pad)))
    blocks = b.reshape(n, -1, k).transpose(1, 0, 2)          # (nb, n, k)
    w = (1 << np.arange(k - 1, -1, -1)).astype(np.int64)
    codes = (blocks.astype(np.int64) * w).sum(-1)            # (nb, n)
    perm = np.argsort(codes, axis=1, kind="stable").astype(np.int32)
    nb = codes.shape[0]
    hist = np.zeros((nb, 2 ** k), np.int32)
    for i in range(nb):                                      # offline, once
        hist[i] = np.bincount(codes[i], minlength=2 ** k)
    seg = np.concatenate([np.zeros((nb, 1), np.int32),
                          np.cumsum(hist, 1).astype(np.int32)], 1)
    return perm, seg, codes.astype(np.uint32)


def bin_matrix_np(k: int) -> np.ndarray:
    j = np.arange(2 ** k, dtype=np.uint32)[:, None]
    return ((j >> np.arange(k - 1, -1, -1)) & 1).astype(np.float32)


def rsr_matvec_np(v: np.ndarray, perm: np.ndarray, seg: np.ndarray,
                  k: int, m: int, plus_plus: bool = False) -> np.ndarray:
    """Inference (Algorithm 2): segmented sums via prefix sums + Bin product."""
    vp = v[perm]                                             # (nb, n) Eq. 5
    ps = np.concatenate([np.zeros((vp.shape[0], 1), vp.dtype),
                         np.cumsum(vp, axis=1)], axis=1)
    u = np.take_along_axis(ps, seg[:, 1:], 1) - \
        np.take_along_axis(ps, seg[:, :-1], 1)               # (nb, 2^k)
    if plus_plus:
        outs = []
        x = u
        for _ in range(k):                                   # Algorithm 3
            pairs = x.reshape(x.shape[0], -1, 2)
            outs.append(pairs[:, :, 1].sum(1))
            x = pairs.sum(2)
        r = np.stack(outs[::-1], axis=1)
    else:
        r = u @ bin_matrix_np(k)
    return r.reshape(-1)[:m]


def standard_matvec_np(v: np.ndarray, b: np.ndarray) -> np.ndarray:
    """BLAS baseline (np.dot) — stronger than the paper's scalar C++."""
    return v @ b


def naive_matvec_np(v: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Non-BLAS vectorized O(n·m): the closest analog of the paper's
    'Standard' scalar implementation."""
    return (v[:, None] * b).sum(axis=0)


def index_bytes_np(perm: np.ndarray, seg: np.ndarray) -> int:
    return perm.nbytes + seg.nbytes
