"""End-to-end system test: QAT-train a tiny ternary LM, convert to RSR serve
indices, generate — the full pipeline the paper proposes (train once,
preprocess once, serve forever)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, TrainConfig, get_config
from repro.models import transformer as tfm
from repro.serve.engine import Engine
from repro.train import data as data_lib
from repro.train.loop import train_state_init, train_step


def test_train_then_rsr_serve_end_to_end():
    cfg = dataclasses.replace(get_config("gemma-2b").reduced(),
                              vocab_size=64, num_layers=2, d_ff=64)
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=2, total_steps=40)
    state = train_state_init(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(lambda st, b: train_step(st, b, cfg=cfg, tcfg=tcfg))
    first = last = None
    for i in range(25):
        batch = jax.tree.map(jnp.asarray,
                             data_lib.synthetic_batch(cfg, 8, 16, i))
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first, (first, last)

    # offline preprocessing (Algorithm 1) of the trained weights
    serve_tree = tfm.serve_params(state["params"], cfg)
    codes = [l for p, l in
             jax.tree_util.tree_flatten_with_path(serve_tree)[0]
             if str(getattr(p[-1], "key", "")) == "codes"]
    assert codes, "serve tree must contain RSR code arrays"
    assert all(l.dtype == jnp.uint8 for l in codes)

    # serve: greedy generation runs and equals the dense-dequant server
    eng = Engine(cfg, serve_tree, ServeConfig(max_seq_len=48, batch_size=2))
    sp_dense = tfm.serve_params(state["params"],
                                dataclasses.replace(cfg, rsr_serve=False))
    eng_d = Engine(cfg, sp_dense, ServeConfig(max_seq_len=48, batch_size=2))
    prompts = jnp.ones((2, 4), jnp.int32)
    np.testing.assert_array_equal(eng.generate(prompts, 8),
                                  eng_d.generate(prompts, 8))
