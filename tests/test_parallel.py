"""Sharding rules + multi-device collectives (subprocess with 8 host devs)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import TrainConfig, get_config
from repro.models import transformer as tfm
from repro.parallel import sharding as shd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_cover_every_leaf_and_divide():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("gemma-2b", "deepseek-v2-lite-16b", "mamba2-780m",
                 "recurrentgemma-2b", "granite-moe-3b-a800m",
                 "llama-3.2-vision-90b"):
        cfg = get_config(arch)
        params = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                                jax.random.PRNGKey(0))
        specs = shd.param_pspecs(params, mesh)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s):
            assert isinstance(spec, P), (path, spec)
            assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)


def test_param_specs_divisibility_on_production_mesh_shapes():
    """Every sharded dim divides its mesh axis on the 16x16 mesh."""
    class FakeMesh:  # shape-only stand-in (no devices needed)
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    mesh = FakeMesh()
    for arch in ("qwen2-72b", "deepseek-67b", "granite-moe-3b-a800m"):
        cfg = get_config(arch)
        params = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                                jax.random.PRNGKey(0))
        specs = shd.param_pspecs(params, mesh)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat_p, flat_s):
            pad = (None,) * (len(leaf.shape) - len(spec))
            for dim, ax in zip(leaf.shape, pad + tuple(spec)):
                if ax is None:
                    continue
                size = np.prod([mesh.shape[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))])
                assert dim % size == 0, (path, leaf.shape, spec)


def test_serve_specs_drop_fsdp():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("gemma-2b")
    params = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = shd.param_pspecs(params, mesh, serve=True)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in [a for axes in spec if axes
                              for a in (axes if isinstance(axes, tuple)
                                        else (axes,))]


_SUBPROC_COLLECTIVES = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.collectives import (compressed_psum,
                                            collective_matmul)

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    # --- compressed psum: int8 all-reduce approximates exact psum ---
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    want = shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                     in_specs=P("data", None),
                     out_specs=P("data", None))(x)
    got = shard_map(lambda v: compressed_psum(v, "data"), mesh=mesh,
                    in_specs=P("data", None),
                    out_specs=P("data", None))(x)
    err = float(jnp.abs(want - got).max() / (jnp.abs(want).max() + 1e-9))
    assert err < 0.05, f"compressed psum err {err}"

    # --- collective matmul == plain matmul ---
    xx = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
    ww = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    got2 = collective_matmul(xx, ww, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(got2), np.asarray(xx @ ww),
                               rtol=1e-4, atol=1e-4)

    # --- tiny sharded train step lowers + compiles + runs on 2x4 mesh ---
    import dataclasses, functools
    from repro.config import TrainConfig, get_config
    from repro.models import transformer as tfm
    from repro.parallel import sharding as shd
    from repro.train.loop import train_state_init, train_step
    from repro.train.optimizer import OptState
    from repro.train import data as data_lib

    cfg = dataclasses.replace(get_config("gemma-2b").reduced(),
                              vocab_size=64, num_layers=2, d_ff=64)
    tcfg = TrainConfig()
    state = train_state_init(cfg, tcfg, jax.random.PRNGKey(0))
    batch = jax.tree.map(jnp.asarray,
                         data_lib.synthetic_batch(cfg, 8, 16, 0))
    with mesh:
        p_specs = shd.param_pspecs(state["params"], mesh)
        sspec = {"params": p_specs,
                 "opt": OptState(step=P(), mu=p_specs, nu=p_specs)}
        bspec = shd.batch_pspecs(batch, mesh)
        f = jax.jit(functools.partial(train_step, cfg=cfg, tcfg=tcfg),
                    in_shardings=(shd.shardings(sspec, mesh),
                                  shd.shardings(bspec, mesh)))
        state2, metrics = f(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    print("SUBPROC_OK")
""")


def test_multidevice_collectives_and_sharded_train_step():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SUBPROC_COLLECTIVES],
                       capture_output=True, text=True, env=env, timeout=600)
    assert "SUBPROC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_ef_compression_error_feedback_converges():
    from repro.parallel.collectives import ef_compress_tree, _EF_STATE
    _EF_STATE.clear()
    g = {"w": jnp.full((16,), 0.001)}
    total = np.zeros(16)
    for _ in range(50):
        out = ef_compress_tree(g, "test")
        total += np.asarray(out["w"])
    # with error feedback, the accumulated output tracks the true sum
    np.testing.assert_allclose(total, 0.001 * 50 * np.ones(16), rtol=0.05)
    _EF_STATE.clear()


_SUBPROC_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply, bubble_fraction

    mesh = jax.make_mesh((4, 2), ("pod", "model"))
    n_stages = 4
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (n_stages, 16, 16)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))

    def layer(w, h):
        return jnp.tanh(h @ w)

    want = x
    for s in range(n_stages):
        want = layer(ws[s], want)
    got = pipeline_apply(ws, x, layer, mesh, axis="pod", microbatches=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert abs(bubble_fraction(4, 4) - 3 / 7) < 1e-9
    print("PIPELINE_OK")
""")


def test_pipeline_parallel_matches_sequential():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SUBPROC_PIPELINE],
                       capture_output=True, text=True, env=env, timeout=600)
    assert "PIPELINE_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]
