"""Roofline machinery: HLO collective parser + cost_analysis calibration."""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.roofline import hw
from repro.roofline.analysis import (Roofline, collective_bytes, _wire_bytes)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SYNTH_HLO = """
HloModule test
ENTRY %main {
  %ag = f32[128,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%y), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%add
  %rs = f32[16,16]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[4,2]<=[8], dimensions={0}
  %cp = u8[1000]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  ROOT %a2a = f32[64]{0} all-to-all(%v), channel_id=5, replica_groups=[2,4]<=[8], dimensions={0}
}
"""


def test_collective_parser_counts_and_bytes():
    out = collective_bytes(SYNTH_HLO)
    c = out["counts"]
    assert c == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                 "all-to-all": 1, "collective-permute": 1}
    assert out["all-gather"] == 128 * 256 * 4 * 3 / 4          # (g-1)/g, g=4
    assert out["all-reduce"] == 2 * 1024 * 2 * 7 / 8           # g=8
    assert out["reduce-scatter"] == 16 * 16 * 4 * 1            # (g-1), g=2
    assert out["collective-permute"] == 1000
    assert out["all-to-all"] == 64 * 4 * 3 / 4
    assert out["total"] == sum(out[k] for k in c)


def test_wire_bytes_formulas():
    assert _wire_bytes("all-gather", 100, 1) == 0
    assert _wire_bytes("all-reduce", 100, 2) == 100.0
    assert _wire_bytes("collective-permute", 100, 2) == 100


def test_roofline_terms_and_dominance():
    r = Roofline(arch="a", shape="s", mesh="m", chips=256,
                 hlo_flops=197e12, hlo_bytes=0.0, coll_bytes=0.0,
                 model_flops=98.5e12).finalize()
    assert r.compute_s == 1.0 and r.dominant == "compute"
    assert abs(r.roofline_fraction - 0.5) < 1e-9
    r2 = Roofline(arch="a", shape="s", mesh="m", chips=1,
                  hlo_flops=0.0, hlo_bytes=819e9, coll_bytes=50e9,
                  model_flops=1.0).finalize()
    assert r2.dominant == "memory" and abs(r2.memory_s - 1.0) < 1e-9
    assert abs(r2.collective_s - 1.0) < 1e-9


_CALIBRATE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    kw = {}
    if hasattr(jax.sharding, "AxisType"):        # newer jax only
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
    mesh = jax.make_mesh((2, 4), ("data", "model"), **kw)
    xs = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    ws = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    co = jax.jit(lambda x, w: x @ w,
                 in_shardings=(NamedSharding(mesh, P("data", None)),
                               NamedSharding(mesh, P(None, "model")))
                 ).lower(xs, ws).compile()
    ca = co.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca["flops"])
    per_dev = 2 * 256 * 512 * 1024 / 8
    # cost_analysis must be per-device (within 10%)
    assert abs(flops - per_dev) / per_dev < 0.1, (flops, per_dev)
    print("CALIBRATION_OK")
""")


def test_cost_analysis_is_per_device():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _CALIBRATE],
                       capture_output=True, text=True, env=env, timeout=300)
    assert "CALIBRATION_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-2000:]


_HLO_COST_CALIBRATE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.roofline.hlo_cost import analyze_hlo

    # scan of 13 matmuls: flops must be trip-count-corrected exactly
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    co = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32),
                          jax.ShapeDtypeStruct((13, 128, 128), jnp.float32)
                          ).compile()
    r = analyze_hlo(co.as_text())
    want = 13 * 2 * 128 ** 3
    assert abs(r["flops"] - want) / want < 0.01, (r["flops"], want)
    assert r["loops"] and r["loops"][0][0] == 13
    print("HLO_COST_OK")
""")


def test_hlo_cost_model_trip_count_exact():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _HLO_COST_CALIBRATE],
                       capture_output=True, text=True, env=env, timeout=300)
    assert "HLO_COST_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-2000:]
