"""Per-arch smoke tests (reduced configs) + decode/serve parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES, get_config, list_archs, shape_applicable
from repro.models import frontend
from repro.models import transformer as tfm

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, b=B, s=S):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    batch = {"labels": toks}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = frontend.audio_frames(cfg, b, s, key=KEY)
    else:
        batch["tokens"] = toks
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = frontend.vision_patches(cfg, b, key=KEY)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward(arch):
    """One forward/loss step on the reduced config: shapes + finiteness."""
    cfg = get_config(arch).reduced()
    params = tfm.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, aux = tfm.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    loss, (ce, _) = tfm.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ["gemma-2b", "qwen2-72b", "mamba2-780m",
                                  "recurrentgemma-2b", "granite-moe-3b-a800m"])
def test_arch_smoke_train_grad(arch):
    """Gradients exist and are finite for every trainable leaf."""
    cfg = get_config(arch).reduced()
    params = tfm.init_params(cfg, KEY)
    batch = make_batch(cfg)
    g = jax.grad(lambda p: tfm.loss_fn(p, batch, cfg)[0])(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), path


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced()
    params = tfm.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    sp = tfm.serve_params(params, dataclasses.replace(cfg, rsr_serve=False))
    full, _ = tfm.forward(sp, {"tokens": toks}, cfg, quantize=False)
    cache = tfm.init_cache(cfg, B, max_seq=S + 4)
    for t in range(S):
        lg, cache = tfm.decode_step(sp, cache, toks[:, t:t + 1], cfg)
    np.testing.assert_allclose(lg, full[:, -1], rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-780m",
                                  "deepseek-v2-lite-16b",
                                  "granite-moe-3b-a800m"])
def test_rsr_serve_matches_dense_serve(arch):
    """The paper's technique end-to-end: RSR-indexed decode == dense-dequant
    decode (same ternary function, two evaluation strategies)."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              capacity_factor=64.0)
    params = tfm.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0,
                              cfg.vocab_size)
    sp_d = tfm.serve_params(params, dataclasses.replace(cfg, rsr_serve=False))
    sp_r = tfm.serve_params(params, cfg)
    c1 = tfm.init_cache(cfg, B, max_seq=12)
    c2 = tfm.init_cache(cfg, B, max_seq=12)
    for t in range(8):
        lg1, c1 = tfm.decode_step(sp_d, c1, toks[:, t:t + 1], cfg)
        lg2, c2 = tfm.decode_step(sp_r, c2, toks[:, t:t + 1], cfg)
    scale = np.abs(np.asarray(lg1)).max() + 1e-6
    assert np.abs(np.asarray(lg1) - np.asarray(lg2)).max() / scale < 2e-4


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mamba2-780m",
                                  "deepseek-v2-lite-16b"])
def test_chunked_prefill_matches_decode_steps(arch):
    """prefill_step with C > 1 must reproduce the single-token decode scan
    across layer families (ring-buffer window wrap, RG-LRU/SSD recurrent
    state, absorbed MLA).  Tight allclose, not bitwise: XLA's dot lowering
    reassociates reductions per row count for some shapes (the bitwise
    guarantee is asserted on the serve config in test_serve)."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              capacity_factor=64.0)
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    S = 24 if arch == "recurrentgemma-2b" else 12   # 24 > window: ring wrap
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    c_ref = tfm.init_cache(cfg, B, max_seq=S + 6)
    for t in range(S):
        lg_ref, c_ref = tfm.decode_step(sp, c_ref, toks[:, t:t + 1], cfg)
    for chunk in (5, S):
        c = tfm.init_cache(cfg, B, max_seq=S + 6)
        for st in range(0, S, chunk):
            lg, c = tfm.prefill_step(sp, c, toks[:, st:st + chunk], cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                                   rtol=1e-5, atol=1e-5)
        for a, bb in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(bb, np.float32),
                                       rtol=1e-5, atol=1e-5)


def test_window_attention_restricts_context():
    """With window w, token i must be independent of tokens < i - w + 1."""
    cfg = dataclasses.replace(get_config("recurrentgemma-2b").reduced(),
                              quant="none")
    params = tfm.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0,
                              cfg.vocab_size)
    l1, _ = tfm.forward(params, {"tokens": toks}, cfg, quantize=False)
    # RG-LRU layers carry unbounded state; and stacked window layers widen
    # the receptive field (2 layers see 2*(w-1) back) — so use ONE attn
    # layer, where position 23 cannot see position 0 with window 16:
    cfg2 = dataclasses.replace(cfg, block_pattern=("attn",),
                               num_layers=1)
    params2 = tfm.init_params(cfg2, KEY)
    toksB = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab_size)
    a, _ = tfm.forward(params2, {"tokens": toks}, cfg2, quantize=False)
    b, _ = tfm.forward(params2, {"tokens": toksB}, cfg2, quantize=False)
    # last position is > window away from position 0 -> logits must match
    assert cfg2.window < 24 - 1
    np.testing.assert_allclose(a[0, -1], b[0, -1], rtol=1e-4, atol=1e-4)
    # but an in-window position must differ
    assert np.abs(np.asarray(a[0, 1]) - np.asarray(b[0, 1])).max() > 1e-6


def test_mamba2_state_decode_long_context_constant_memory():
    """SSM decode state is context-independent (enables long_500k)."""
    cfg = get_config("mamba2-780m").reduced()
    c1 = tfm.init_cache(cfg, 1, max_seq=100)
    c2 = tfm.init_cache(cfg, 1, max_seq=100000)
    s1 = sum(np.asarray(l).nbytes for l in jax.tree.leaves(c1["blocks"]))
    s2 = sum(np.asarray(l).nbytes for l in jax.tree.leaves(c2["blocks"]))
    assert s1 == s2


def test_shape_applicability_rules():
    assert not shape_applicable(get_config("hubert-xlarge"),
                                SHAPES["decode_32k"])[0]
    assert not shape_applicable(get_config("qwen2-72b"),
                                SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("mamba2-780m"),
                            SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("recurrentgemma-2b"),
                            SHAPES["long_500k"])[0]
