"""Paged-attention kernel: unit parity vs the gather-path math (GQA full /
sliding-window ring / MLA latent), engine-level parity vs the gather
reference on the serve config (both RSR backends), backend resolution, and
the query-tile regime table.

Parity bar: the kernel accumulates softmax online across blocks, so it
agrees with the one-shot gather softmax to float associativity (documented
allclose, ~1e-6 f32), NOT bitwise — greedy decodes must still be token-
identical (asserted here; the gather path keeps the bitwise-vs-dense bar in
test_serve.py).  Heavy cross-family × backend sweeps carry @slow per the
PR-3 tiering."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.kernels import paged_attention as pattn
from repro.models import transformer as tfm
from repro.models.attention import _gather_blocks
from repro.serve.engine import BatchScheduler, Engine, Request

KEY = jax.random.PRNGKey(0)
NEG_INF = -1e30

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)


def _engines(scfg_extra=None, cfg=CFG, max_seq=64, batch=2):
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    base = ServeConfig(max_seq_len=max_seq, batch_size=batch, kv_block_size=8)
    if scfg_extra:
        base = dataclasses.replace(base, **scfg_extra)
    e_k = Engine(cfg, sp, dataclasses.replace(base, paged_attn="kernel"))
    e_g = Engine(cfg, sp, dataclasses.replace(base, paged_attn="gather"))
    return e_k, e_g, sp


# ---------------------------------------------------------------------------
# Kernel-level parity vs the gather-path math (no model, no engine)
# ---------------------------------------------------------------------------

def _rand_pool(rng, nb, kvh, bs, hd):
    k = jnp.asarray(rng.standard_normal((nb + 1, kvh, bs, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((nb + 1, kvh, bs, hd)), jnp.float32)
    return k, v


def test_kernel_gqa_full_matches_gather_math():
    """(B, C) chunk vs the exact gather-then-score einsums of gqa_apply,
    across query tilings (tiling must not change per-query results)."""
    rng = np.random.default_rng(0)
    B, C, H, KVH, HD, BS, MB, NB = 2, 5, 4, 2, 16, 4, 6, 16
    g = H // KVH
    kp, vp = _rand_pool(rng, NB, KVH, BS, HD)
    table = jnp.asarray(rng.permutation(NB)[:B * MB].reshape(B, MB),
                        jnp.int32)
    positions = jnp.asarray([[7, 8, 9, 10, 11], [3, 4, 5, 6, 7]], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, C, H, HD)),
                    jnp.float32) / math.sqrt(HD)

    ckd, cvd = _gather_blocks(kp, table), _gather_blocks(vp, table)
    s = jnp.einsum("bchgd,bhkd->bchgk", q.reshape(B, C, KVH, g, HD), ckd,
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(ckd.shape[2])[None, None, :] <= positions[:, :, None]
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bchgk,bhkd->bchgd", pr, cvd,
                     preferred_element_type=jnp.float32).reshape(B, C, H, HD)

    for tc in (None, 1, 2, C):
        out = pattn.paged_gqa_attend(q, kp, vp, table, positions, tile_c=tc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-6, atol=2e-6)
        np.testing.assert_array_equal(
            np.asarray(out).reshape(B, C, -1).argmax(-1),
            np.asarray(ref).reshape(B, C, -1).argmax(-1))


def test_kernel_gqa_ring_matches_gather_math():
    """Sliding-window ring masking (incl. pre-fill, exact-wrap, and
    many-times-wrapped positions) vs the dense scan-step formula."""
    rng = np.random.default_rng(1)
    B, H, KVH, HD, BS, MB, NB = 2, 4, 2, 16, 4, 6, 16
    g = H // KVH
    W = MB * BS
    kp, vp = _rand_pool(rng, NB, KVH, BS, HD)
    table = jnp.asarray(rng.permutation(NB)[:B * MB].reshape(B, MB),
                        jnp.int32)
    ckd, cvd = _gather_blocks(kp, table), _gather_blocks(vp, table)
    for pt_val in (0, 3, W - 1, W, 2 * W + 5):
        pt = jnp.asarray([pt_val, max(0, pt_val - 2)], jnp.int32)
        qt = jnp.asarray(rng.standard_normal((B, 1, H, HD)),
                         jnp.float32) / math.sqrt(HD)
        s = jnp.einsum("bchgd,bhkd->bchgk", qt.reshape(B, 1, KVH, g, HD),
                       ckd, preferred_element_type=jnp.float32)
        kpos = jnp.arange(W)[None, :]
        age = (pt[:, None] - kpos) % W
        valid = (age >= 0) & (age < jnp.minimum(pt[:, None] + 1, W))
        valid = valid & ((pt[:, None] - age) >= 0)
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        ref = jnp.einsum("bchgk,bhkd->bchgd", jax.nn.softmax(s, axis=-1),
                         cvd, preferred_element_type=jnp.float32)
        out = pattn.paged_gqa_attend(qt, kp, vp, table, pt[:, None],
                                     ring_slots=W)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref).reshape(B, 1, H, HD),
                                   rtol=2e-6, atol=2e-6)


def test_kernel_mla_matches_gather_math():
    """MLA latent scoring (q_lat·c + q_pe·pe, post-sum scale, latent value
    side) vs the absorbed dense-path einsums."""
    rng = np.random.default_rng(2)
    B, C, H, R, DR, BS, MB, NB = 2, 3, 4, 8, 4, 4, 5, 12
    cp = jnp.asarray(rng.standard_normal((NB + 1, BS, R)), jnp.float32)
    pep = jnp.asarray(rng.standard_normal((NB + 1, BS, DR)), jnp.float32)
    table = jnp.asarray(rng.permutation(NB)[:B * MB].reshape(B, MB),
                        jnp.int32)
    positions = jnp.asarray([[9, 10, 11], [4, 5, 6]], jnp.int32)
    ql = jnp.asarray(rng.standard_normal((B, C, H, R)), jnp.float32)
    qpe = jnp.asarray(rng.standard_normal((B, C, H, DR)), jnp.float32)
    scale = 1.0 / math.sqrt(R + DR)
    c_d = cp[table].reshape(B, -1, R)
    pe_d = pep[table].reshape(B, -1, DR)
    s = (jnp.einsum("bchr,bkr->bchk", ql, c_d,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bchd,bkd->bchk", qpe, pe_d,
                      preferred_element_type=jnp.float32)) * scale
    mask = jnp.arange(c_d.shape[1])[None, None, :] <= positions[:, :, None]
    s = jnp.where(mask[:, :, None, :], s, NEG_INF)
    ref = jnp.einsum("bchk,bkr->bchr", jax.nn.softmax(s, axis=-1), c_d,
                     preferred_element_type=jnp.float32)
    out = pattn.paged_mla_attend(ql, qpe, cp, pep, table, positions,
                                 scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# Engine-level parity: kernel vs gather on the serve config
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas_interpret", "scatter"])
def test_paged_attn_kernel_decodes_match_gather(backend):
    """The acceptance bar: on the serve config, the kernel path must decode
    token-identical greedy sequences vs the gather reference, per RSR
    backend, with tight-allclose prefill logits."""
    cfg = dataclasses.replace(CFG, rsr_backend=backend)
    e_k, e_g, _ = _engines(cfg=cfg)
    assert e_k.paged_attn == "kernel" and e_g.paged_attn == "gather"
    prompts = jax.random.randint(jax.random.PRNGKey(9), (2, 9), 0,
                                 cfg.vocab_size)
    lg_k = np.asarray(e_k.prefill(prompts, start=0))
    lg_g = np.asarray(e_g.prefill(prompts, start=0))
    np.testing.assert_allclose(lg_k, lg_g, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(lg_k.argmax(-1), lg_g.argmax(-1))
    e_k.reset(), e_g.reset()
    t_k = e_k.generate(prompts, max_new=12)
    t_g = e_g.generate(prompts, max_new=12)
    np.testing.assert_array_equal(t_k, t_g)


@pytest.mark.slow
def test_paged_attn_kernel_scheduler_matches_per_request():
    """Continuous batching through the kernel path (mixed lengths, shared
    blocks, COW) must decode per-request-identical tokens vs solo
    generation — the kernel's per-slot grid makes batched-vs-single
    structurally row-count-invariant.  (slow: the fast tier already runs
    the scheduler through the kernel default in test_paged.py.)"""
    e_k, _, sp = _engines({"prefill_chunk": 4, "kv_block_size": 4},
                          max_seq=32)
    sched = BatchScheduler(e_k)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, CFG.vocab_size, n).astype(np.int32)
               for n in (3, 9, 5, 8)]
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    done = sched.run()
    assert len(done) == 4
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=32, batch_size=1,
                                      prefill_chunk=4, kv_block_size=4,
                                      paged_attn="kernel"))
    for r in sorted(done, key=lambda r: r.rid):
        ref.reset()
        want = ref.generate(jnp.asarray(r.prompt)[None, :], r.max_new)[0]
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(want))
    assert e_k.pool.free_count == e_k.pool.num_blocks


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["pallas_interpret", "scatter"])
@pytest.mark.parametrize("arch,block", [("recurrentgemma-2b", 8),
                                        ("deepseek-v2-lite-16b", 4)])
def test_paged_attn_kernel_across_families(arch, block, backend):
    """Ring-buffer (sliding-window) and MLA cache layouts through the
    kernel, per RSR backend: token-identical greedy decodes vs the gather
    reference, tight-allclose prefill logits."""
    cfg = dataclasses.replace(get_config(arch).reduced(), vocab_size=64,
                              capacity_factor=64.0, rsr_backend=backend)
    e_k, e_g, _ = _engines(cfg=cfg, max_seq=32,
                           scfg_extra={"kv_block_size": block})
    prompts = jax.random.randint(jax.random.PRNGKey(10), (2, 20), 0,
                                 cfg.vocab_size)        # 20 > window=16: wrap
    lg_k = np.asarray(e_k.prefill(prompts, start=0))
    lg_g = np.asarray(e_g.prefill(prompts, start=0))
    np.testing.assert_allclose(lg_k, lg_g, rtol=1e-5, atol=1e-5)
    e_k.reset(), e_g.reset()
    t_k = e_k.generate(prompts, max_new=8)
    t_g = e_g.generate(prompts, max_new=8)
    np.testing.assert_array_equal(t_k, t_g)


# ---------------------------------------------------------------------------
# Backend resolution + tile regimes
# ---------------------------------------------------------------------------

def test_select_paged_backend_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_PAGED_ATTN", raising=False)
    assert pattn.select_paged_backend() == "kernel"          # default
    assert pattn.select_paged_backend(None, "gather") == "gather"
    assert pattn.select_paged_backend("kernel", "gather") == "kernel"
    monkeypatch.setenv("REPRO_PAGED_ATTN", "gather")
    assert pattn.select_paged_backend() == "gather"          # env
    assert pattn.select_paged_backend("kernel") == "kernel"  # arg outranks
    assert pattn.select_paged_backend(None, "kernel") == "gather"
    with pytest.raises(ValueError):
        pattn.select_paged_backend("nope")
    monkeypatch.setenv("REPRO_PAGED_ATTN", "bogus")
    with pytest.raises(ValueError):
        pattn.select_paged_backend()


def test_engine_resolves_paged_attn_from_env(monkeypatch):
    """$REPRO_PAGED_ATTN outranks ServeConfig.paged_attn at Engine
    construction (the operator override, mirroring REPRO_RSR_BACKEND)."""
    params = tfm.init_params(CFG, KEY)
    sp = tfm.serve_params(params, CFG)
    scfg = ServeConfig(max_seq_len=32, batch_size=1, kv_block_size=8)
    monkeypatch.setenv("REPRO_PAGED_ATTN", "gather")
    e = Engine(CFG, sp, dataclasses.replace(scfg, paged_attn="kernel"))
    assert e.paged_attn == "gather"
    monkeypatch.delenv("REPRO_PAGED_ATTN")
    assert Engine(CFG, sp, scfg).paged_attn == "kernel"      # auto default
    assert Engine(CFG, sp, ServeConfig(max_seq_len=32,
                                       batch_size=1)).paged_attn is None


def test_attn_tile_regimes_and_overlay():
    assert pattn.select_attn_tiles(1) == 1                   # decode
    assert pattn.select_attn_tiles(5) == 5                   # clamped small
    assert pattn.select_attn_tiles(8) == 8
    assert pattn.select_attn_tiles(100) == 32                # prefill row
    pattn.TUNED_ATTN_TILES[("prefill", 128)] = 16
    try:
        assert pattn.select_attn_tiles(100) == 16            # overlay wins
    finally:
        pattn.TUNED_ATTN_TILES.clear()


def test_attn_tiles_persist_in_autotune_cache(tmp_path):
    """Measured query tiles ride the shared autotune cache file alongside
    the RSR tiles and survive a reload."""
    from repro.kernels import dispatch
    path = str(tmp_path / "cache.json")
    pattn.TUNED_ATTN_TILES[("prefill", 64)] = 16
    try:
        dispatch.save_autotune_cache(path)
        pattn.TUNED_ATTN_TILES.clear()
        n = dispatch.load_autotune_cache(path)
        assert n >= 1
        assert pattn.TUNED_ATTN_TILES[("prefill", 64)] == 16
    finally:
        pattn.TUNED_ATTN_TILES.clear()
