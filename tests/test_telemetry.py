"""Serve-plane telemetry: registry semantics, dict-compatible stats
views, injectable-clock timing, and the trace-determinism contract.

The determinism tests run the full paged engine twice under identical
seeds (overcommit soak for the preempt → warm-revival → tail-reprefill
lifecycle; ``FaultPlan.random`` for the chaos soak) and require the
event sequences — names, ordinals, injected-clock timestamps — to match
exactly, with the chaos run's JSON exports bitwise identical.  That is
the property that makes a trace diff a usable debugging artifact: any
byte of divergence IS the nondeterminism you are hunting.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve import telemetry
from repro.serve.engine import Engine, Request, RequestStatus
from repro.serve.faults import FaultPlan
from repro.serve.frontend import AsyncFrontend, PriorityScheduler
from repro.serve.telemetry import (NULL, Counter, Gauge, Histogram,
                                   MetricsRegistry, StatsView, Telemetry,
                                   Tracer, latency_attribution,
                                   stats_counters)

KEY = jax.random.PRNGKey(0)

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)


def _engine(scfg: ServeConfig, cfg=CFG):
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    return Engine(cfg, sp, scfg), sp


class TickClock:
    """Deterministic fake clock: advances ``dt`` on every call."""

    def __init__(self, dt: float = 0.0, t0: float = 0.0):
        self.t = t0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


# ---------------------------------------------------------------------------
# StatsView: the dict-compat surface the legacy call sites drive
# ---------------------------------------------------------------------------

def test_stats_view_walks_like_the_legacy_dict():
    v = stats_counters("serve_x_stats", ("a", "b"), help="h")
    v["a"] += 2
    v["b"] = 5
    v["c"] = 1                                  # late key, like fired tallies
    assert v["a"] == 2 and v.get("missing", 0) == 0
    assert dict(v) == {"a": 2, "b": 5, "c": 1}
    assert {**v} == {"a": 2, "b": 5, "c": 1}
    assert v == {"a": 2, "b": 5, "c": 1}        # test_chaos literal equality
    assert {"a": 2, "b": 5, "c": 1} == v        # reflected
    assert v != {"a": 0}
    assert sum(v.values()) == 8
    assert repr(v) == repr({"a": 2, "b": 5, "c": 1})
    assert json.dumps(dict(v))                  # snapshot-serializable
    v.update({"a": 9})
    assert v["a"] == 9


def test_stats_view_exports_as_labelled_counter_family():
    v = stats_counters("serve_x_stats", ("hits",), help="h")
    v["hits"] += 3
    text = "\n".join(v.render())
    assert '# TYPE serve_x_stats counter' in text
    assert 'serve_x_stats{key="hits"} 3' in text
    assert v.to_json()["samples"] == [{"labels": {"key": "hits"},
                                       "value": 3}]


# ---------------------------------------------------------------------------
# Registry: enabled families vs the shared disabled no-op
# ---------------------------------------------------------------------------

def test_disabled_registry_hands_back_the_shared_null():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("serve_c", "h")
    assert c is NULL and reg.gauge("serve_g") is NULL
    assert reg.histogram("serve_h") is NULL
    c.inc()
    c.labels(anything="x").observe(1.0)          # whole chain is a no-op
    assert reg.render_prometheus() == "" and reg.to_json() == {}


def test_enabled_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("serve_c", "h", ("lane",))
    assert reg.counter("serve_c") is c           # get-or-create by name
    c.labels(lane="0").inc(2)
    c.labels(lane="1").inc()
    assert c.value(lane="0") == 2
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("serve_c")


def test_prometheus_render_counter_gauge_histogram():
    reg = MetricsRegistry(enabled=True)
    reg.counter("serve_c", "hc", ("lane",)).labels(lane="0").inc(2)
    reg.gauge("serve_g", "hg").set(7)
    h = reg.histogram("serve_h", "hh", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.render_prometheus()
    assert 'serve_c{lane="0"} 2' in text
    assert "serve_g 7" in text
    # cumulative le buckets, integral floats printed as ints
    assert 'serve_h_bucket{le="0.1"} 1' in text
    assert 'serve_h_bucket{le="1"} 2' in text
    assert 'serve_h_bucket{le="+Inf"} 3' in text
    assert "serve_h_sum 5.55" in text and "serve_h_count 3" in text
    js = reg.to_json()
    assert js["serve_h"]["type"] == "histogram"
    assert js["serve_h"]["samples"][0]["sum"] == pytest.approx(5.55)


def test_adopted_views_export_even_when_disabled():
    """Stats views count always; adopt() wires them into the export
    regardless of the enabled flag — the dashboard sees lifecycle
    counters even on a telemetry-off plane."""
    tel = Telemetry(enabled=False)
    v = stats_counters("serve_x_stats", ("ticks",))
    tel.adopt(v)
    v["ticks"] += 4
    assert 'serve_x_stats{key="ticks"} 4' in tel.render_prometheus()


# ---------------------------------------------------------------------------
# Enablement precedence and trace-path plumbing
# ---------------------------------------------------------------------------

def test_from_config_env_outranks_config(monkeypatch):
    scfg = ServeConfig(max_seq_len=32, batch_size=1, telemetry=True)
    monkeypatch.delenv("REPRO_TELEMETRY", raising=False)
    assert Telemetry.from_config(scfg).enabled
    monkeypatch.setenv("REPRO_TELEMETRY", "0")
    assert not Telemetry.from_config(scfg).enabled       # env vetoes config
    monkeypatch.setenv("REPRO_TELEMETRY", "yes")
    scfg = ServeConfig(max_seq_len=32, batch_size=1, telemetry=False)
    assert Telemetry.from_config(scfg).enabled           # env enables


def test_trace_path_written_on_dump(tmp_path, monkeypatch):
    target = tmp_path / "trace.json"
    monkeypatch.setenv("REPRO_TRACE_PATH", str(target))
    tel = Telemetry.from_config(
        ServeConfig(max_seq_len=32, batch_size=1, telemetry=True))
    tel.event("submit", 1.0, rid=0)
    blob = tel.dump_trace()
    assert target.read_text() == blob
    doc = json.loads(blob)
    assert doc["schema"] == "repro_trace_v1"
    assert doc["events"] == [{"seq": 1, "ev": "submit", "t": 1.0, "rid": 0}]


def test_tracer_disabled_records_nothing():
    tr = Tracer(enabled=False)
    tr.event("submit", 1.0, rid=0)
    assert tr.events == []
    assert json.loads(tr.export_json())["events"] == []


# ---------------------------------------------------------------------------
# latency attribution over a synthetic trace
# ---------------------------------------------------------------------------

def test_latency_attribution_stages_and_lanes():
    ev = [
        {"seq": 1, "ev": "submit", "t": 0.0, "rid": 0, "lane": 0},
        {"seq": 2, "ev": "submit", "t": 0.0, "rid": 1, "lane": 2},
        {"seq": 3, "ev": "admit", "t": 1.0, "rid": 0},
        {"seq": 4, "ev": "first_token", "t": 3.0, "rid": 0},
        {"seq": 5, "ev": "admit", "t": 2.0, "rid": 1},
        {"seq": 6, "ev": "first_token", "t": 5.0, "rid": 1},
        {"seq": 7, "ev": "finish", "t": 7.0, "rid": 0},
        {"seq": 8, "ev": "finish", "t": 11.0, "rid": 1},
    ]
    att = latency_attribution(ev)
    assert set(att) == {0, 2}
    assert att[0]["queue"] == {"n": 1, "mean": 1.0, "p50": 1.0, "p99": 1.0}
    assert att[0]["prefill"]["p50"] == 2.0
    assert att[0]["decode"]["p50"] == 4.0
    assert att[2]["total"] == {"n": 1, "mean": 11.0, "p50": 11.0,
                               "p99": 11.0}
    assert latency_attribution([]) == {}


# ---------------------------------------------------------------------------
# Engine timing runs on the injectable clock (the PR-10 bugfix)
# ---------------------------------------------------------------------------

def test_decode_throughput_measures_on_injected_clock():
    """decode_throughput used to hardcode time.perf_counter; with the
    scheduler-style clock injected, the measurement is exactly the fake
    clock's arithmetic — deterministic and fault-skewable."""
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=2))
    e.clock = TickClock(dt=0.5)
    out = e.decode_throughput(steps=4, warmup=1)
    assert out["us_per_step"] == pytest.approx(0.5 / 4 * 1e6)
    assert out["tokens_per_s"] == pytest.approx(2 * 4 / 0.5)


def test_scheduler_attaches_its_clock_to_the_engine():
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1))
    clock = TickClock(dt=0.001)
    sched = PriorityScheduler(e, clock=clock)
    assert e.clock is sched.clock


# ---------------------------------------------------------------------------
# Trace determinism: the overcommit soak, twice (ISSUE-10 satellite)
# ---------------------------------------------------------------------------

def _soak_scfg(**over) -> ServeConfig:
    # the ISSUE-6 soak geometry: 3 requests x worst-case 4 blocks = 12 >
    # pool of 9, so at 1.5x overcommit all three admit lazily and collide
    # mid-decode -> preemption + warm re-admission.
    return ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=9, paged_attn="gather",
                       overcommit=1.5, telemetry=True, **over)


def _traced_soak_run(fault_seed=None):
    """One full soak run on a fresh engine with a fresh deterministic
    clock; returns (scheduler, done-by-rid, trace blob)."""
    e, _ = _engine(_soak_scfg())
    plan = None if fault_seed is None else FaultPlan.random(fault_seed)
    sched = PriorityScheduler(e, clock=TickClock(dt=1e-3, t0=100.0),
                              fault_plan=plan)
    rng = np.random.default_rng(11)
    for i in range(3):
        sched.submit(Request(rid=i,
                             prompt=rng.integers(1, 64, 9).astype(np.int32),
                             max_new=20))
    done = {r.rid: r for r in sched.run()}
    return sched, done, sched.telemetry.dump_trace()


def test_trace_covers_preempt_warm_revival_lifecycle():
    """The soak's trace must tell the whole story: submits, cold admits,
    first tokens, at least one preemption, a warm re-admission that
    re-hit prefix tokens, and OK finishes with the full token count."""
    sched, done, blob = _traced_soak_run()
    assert all(done[i].status is RequestStatus.OK for i in range(3))
    ev = sched.telemetry.trace.events
    assert [e["seq"] for e in ev] == list(range(1, len(ev) + 1))
    by_name = {}
    for e in ev:
        by_name.setdefault(e["ev"], []).append(e)
    assert {e["rid"] for e in by_name["submit"]} == {0, 1, 2}
    assert len(by_name["preempt"]) >= 1          # the pool DID run dry
    readmits = [e for e in by_name["admit"] if e["readmit"]]
    assert readmits and any(e["hit_tokens"] > 0 for e in readmits)
    assert {e["rid"] for e in by_name["first_token"]} == {0, 1, 2}
    assert all(e["status"] == "OK" and e["tokens"] == 20
               for e in by_name["finish"])
    assert by_name["decode"], "tick-level decode events missing"
    # attribution over the real trace: every stage observed for lane 0
    att = latency_attribution(ev)
    assert att[0]["queue"]["n"] == 3 and att[0]["decode"]["n"] == 3
    assert att[0]["total"]["p99"] > 0


def test_trace_identical_across_same_seed_runs():
    """Same seed, same clock, fresh engine: the full event sequence —
    names, ordinals, injected-clock timestamps, field payloads — must
    match element for element across two independent runs."""
    s1, d1, blob1 = _traced_soak_run()
    s2, d2, blob2 = _traced_soak_run()
    assert s1.telemetry.trace.events == s2.telemetry.trace.events
    assert blob1 == blob2
    assert {i: d1[i].status for i in d1} == {i: d2[i].status for i in d2}


def test_chaos_soak_trace_export_bitwise_identical():
    """Same seed + same FaultPlan ⇒ byte-identical canonical-JSON trace
    exports and identical fault tallies (the PR-10 acceptance soak)."""
    s1, d1, blob1 = _traced_soak_run(fault_seed=3)
    s2, d2, blob2 = _traced_soak_run(fault_seed=3)
    assert blob1 == blob2
    assert s1.fault_plan.fired == dict(s2.fault_plan.fired)
    assert {i: d1[i].status for i in d1} == {i: d2[i].status for i in d2}


# ---------------------------------------------------------------------------
# Frontend export surface + disabled-mode contract
# ---------------------------------------------------------------------------

def _run_async(coro):
    import asyncio
    return asyncio.run(asyncio.wait_for(coro, 120.0))


def test_frontend_metrics_and_trace_export():
    scfg = ServeConfig(max_seq_len=32, batch_size=2, telemetry=True)
    e, _ = _engine(scfg)
    fe = AsyncFrontend(e, clock=TickClock(dt=1e-3))

    async def go():
        fe.submit(np.ones(4, np.int32), 4)
        fe.submit(np.ones(6, np.int32), 3, priority=1)
        return await fe.drain()

    done = _run_async(go())
    assert all(r.status is RequestStatus.OK for r in done)
    text = fe.metrics()
    assert 'serve_sched_stats{key="ticks"}' in text
    assert "# TYPE serve_tick_duration_seconds histogram" in text
    assert "serve_batch_occupancy" in text
    assert "# TYPE rsr_dispatch_calls counter" in text   # kernel families
    js = fe.metrics_json()
    assert js["serve_request_latency_seconds"]["type"] == "histogram"
    doc = json.loads(fe.dump_trace())
    assert doc["schema"] == "repro_trace_v1"
    assert {e["ev"] for e in doc["events"]} >= {"submit", "admit",
                                                "first_token", "finish"}
    att = latency_attribution(fe.telemetry.trace.events)
    assert att[0]["queue"]["n"] == 1 and att[1]["queue"]["n"] == 1


def test_disabled_plane_counts_stats_but_traces_nothing():
    """Telemetry off (the default): lifecycle counters still count (the
    tests/benches assert them), but no events, no histograms, no gauges
    — and the stats views still export for whoever asks."""
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1))
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=np.ones(4, np.int32), max_new=4))
    done = sched.run()
    assert done[0].status is RequestStatus.OK
    assert not sched.telemetry.enabled
    assert sched.stats["ticks"] > 0              # views count always
    assert sched.telemetry.trace.events == []
    text = sched.telemetry.render_prometheus()
    assert 'serve_sched_stats{key="admissions"} 1' in text
    assert "serve_tick_phase_seconds" not in text  # gated extras stayed off
    assert sched.telemetry.histogram("serve_anything") is NULL
