"""Preprocessing invariants (Algorithm 1) as hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: deterministic sweep
    from hypothesis_fallback import given, settings, st

from repro.core import (binary_row_codes, preprocess_binary,
                        preprocess_ternary_direct, random_binary,
                        random_ternary)


@given(n=st.sampled_from([2, 9, 24]), m=st.sampled_from([1, 8, 19]),
       k=st.sampled_from([1, 2, 5]))
@settings(max_examples=12, deadline=None)
def test_permutation_sorts_codes_stably(n, m, k):
    b = random_binary(jax.random.PRNGKey(n * 31 + m), (n, m))
    idx = preprocess_binary(b, k)
    for i in range(idx.num_blocks):
        perm = np.asarray(idx.perm[i])
        codes = np.asarray(idx.codes[i]).astype(np.int64)
        sorted_codes = codes[perm]
        # Def 3.2: ascending binary row order
        assert (np.diff(sorted_codes) >= 0).all()
        # permutation is a bijection
        assert sorted(perm.tolist()) == list(range(n))
        # stability: equal codes keep original order
        for v in np.unique(codes):
            rows = perm[sorted_codes == v]
            assert (np.diff(rows) > 0).all()


@given(n=st.sampled_from([2, 9, 24]), m=st.sampled_from([1, 8, 19]),
       k=st.sampled_from([1, 2, 5]))
@settings(max_examples=12, deadline=None)
def test_full_segmentation_semantics(n, m, k):
    """Def 3.4/Fig 2: seg[j] = first sorted index with pattern j; empty
    patterns collapse; sentinel = n; counts = histogram (Prop 3.5)."""
    b = random_binary(jax.random.PRNGKey(n * 131 + m + k), (n, m))
    idx = preprocess_binary(b, k)
    for i in range(idx.num_blocks):
        seg = np.asarray(idx.seg[i])
        codes = np.asarray(idx.codes[i]).astype(np.int64)
        assert seg.shape == (2 ** k + 1,)
        assert seg[0] == 0 and seg[-1] == n
        assert (np.diff(seg) >= 0).all()
        hist = np.bincount(codes, minlength=2 ** k)
        np.testing.assert_array_equal(np.diff(seg), hist)   # Prop 3.5


@given(n=st.sampled_from([2, 16]), m=st.sampled_from([3, 13]),
       k=st.sampled_from([1, 3]))
@settings(max_examples=8, deadline=None)
def test_codes_recover_sigma_and_L(n, m, k):
    """codes ↔ (σ, L) mutual recoverability (DESIGN §2 storage claim)."""
    b = random_binary(jax.random.PRNGKey(n + m * 7 + k), (n, m))
    idx = preprocess_binary(b, k)
    perm2 = np.argsort(np.asarray(idx.codes), axis=-1, kind="stable")
    np.testing.assert_array_equal(perm2, np.asarray(idx.perm))


@given(n=st.sampled_from([5, 18]), k=st.sampled_from([2, 3]))
@settings(max_examples=6, deadline=None)
def test_column_padding_is_inert(n, k):
    """Zero-padded columns (m % k != 0) never contribute to the product."""
    m = k + 1 if k > 1 else 1     # force padding
    a = random_ternary(jax.random.PRNGKey(n * 3 + k), (n, m))
    idx = preprocess_ternary_direct(a, k)
    v = jax.random.normal(jax.random.PRNGKey(0), (n,))
    from repro.core import rsr_matmul_ternary_direct
    got = rsr_matmul_ternary_direct(v, idx)
    assert got.shape == (m,)
    np.testing.assert_allclose(got, v @ a.astype(jnp.float32), rtol=2e-4,
                               atol=2e-4)


def test_row_codes_big_endian():
    block = jnp.array([[1, 0, 1, 1]], dtype=jnp.int8)
    assert int(binary_row_codes(block)[0]) == 0b1011   # paper Def 3.2 example
