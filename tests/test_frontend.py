"""Request plane: priority lanes, EDF + aging, deadline/timeout paths,
lazy allocation with overcommit, victim preemption with warm-list
re-admission parity, and the asyncio frontend.

Scheduling-policy tests drive ``PriorityScheduler`` with a fake clock so
lane aging, EDF ordering, and deadline enforcement are deterministic; the
overcommit soak test and the preemption-churn test run the full paged
engine (gather mode — the bitwise parity bar) and check greedy-token
parity against unconstrained solo runs.  asyncio tests are wrapped in
``asyncio.wait_for`` so a dead serve loop fails fast instead of hanging
CI (the ISSUE-6 timeout guard).
"""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve.engine import Engine, Request, RequestStatus
from repro.serve.frontend import AsyncFrontend, PriorityScheduler

KEY = jax.random.PRNGKey(0)

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)

ASYNC_TIMEOUT_S = 120.0               # dead-loop guard around asyncio tests


def _engine(scfg: ServeConfig, cfg=CFG):
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    return Engine(cfg, sp, scfg), sp


class TickClock:
    """Deterministic fake clock: advances ``dt`` on every call."""

    def __init__(self, dt: float = 0.0, t0: float = 0.0):
        self.t = t0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


def _run_async(coro):
    return asyncio.run(asyncio.wait_for(coro, ASYNC_TIMEOUT_S))


# ---------------------------------------------------------------------------
# Machine-readable status enum (ISSUE-6 satellite bugfix)
# ---------------------------------------------------------------------------

def test_terminal_status_enum_on_rejection_and_completion():
    """Clients must be able to branch on ``status`` without parsing the
    free-text ``error`` detail (which stays set)."""
    scfg = ServeConfig(max_seq_len=32, batch_size=2, kv_block_size=8,
                       kv_num_blocks=2, paged_attn="gather")
    e, _ = _engine(scfg)
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=np.zeros((0,), np.int32), max_new=2))
    sched.submit(Request(rid=1, prompt=np.ones(40, np.int32), max_new=4))
    sched.submit(Request(rid=2, prompt=np.ones(20, np.int32), max_new=4))
    sched.submit(Request(rid=3, prompt=np.ones(5, np.int32), max_new=3))
    done = {r.rid: r for r in sched.run()}
    assert done[0].status is RequestStatus.REJECTED_VALIDATION
    assert done[1].status is RequestStatus.REJECTED_VALIDATION
    assert "max_seq_len" in done[1].error
    assert done[2].status is RequestStatus.REJECTED_CAPACITY
    assert "blocks" in done[2].error
    assert done[3].status is RequestStatus.OK and done[3].error is None
    assert all(done[r].status.terminal for r in done)
    assert not RequestStatus.PREEMPTED.terminal


# ---------------------------------------------------------------------------
# Admission ordering: lanes, EDF, aging
# ---------------------------------------------------------------------------

def test_priority_lanes_order_admission():
    """batch=1 serializes admissions, so finish order == admission order:
    lower lane number wins regardless of submit order."""
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1))
    sched = PriorityScheduler(e, clock=TickClock(0.0))
    for rid, pri in [(0, 2), (1, 0), (2, 1)]:
        sched.submit(Request(rid=rid, prompt=np.ones(4, np.int32) * (rid + 1),
                             max_new=2, priority=pri))
    done = sched.run()
    assert [r.rid for r in done] == [1, 2, 0]
    assert all(r.status is RequestStatus.OK for r in done)


def test_edf_orders_within_lane():
    """Same lane: earliest deadline first, deadline-free requests last."""
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1))
    sched = PriorityScheduler(e, clock=TickClock(0.0))
    for rid, dl in [(0, 50.0), (1, 10.0), (2, None)]:
        sched.submit(Request(rid=rid, prompt=np.ones(4, np.int32) * (rid + 1),
                             max_new=2, deadline_s=dl))
    done = sched.run()
    assert [r.rid for r in done] == [1, 0, 2]


def test_lane_aging_promotes_and_pinning_jumps_queue():
    """A lane-3 request reaches lane 0 after 3 * lane_aging_s of queue
    wait; a pinned request (>= max_preemptions evictions) outranks lane 0."""
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1,
                               lane_aging_s=2.0))
    sched = PriorityScheduler(e)
    req = Request(rid=0, prompt=np.ones(4, np.int32), max_new=2, priority=3,
                  arrival=0.0)
    assert sched._lane(req, 0.0) == 3
    assert sched._lane(req, 2.0) == 2
    assert sched._lane(req, 6.0) == 0
    assert sched._lane(req, 100.0) == 0          # never below lane 0 unpinned
    req.preemptions = sched.max_preemptions
    assert sched._lane(req, 0.0) == -1           # pinned: ahead of every lane
    fresh = Request(rid=1, prompt=np.ones(4, np.int32), max_new=2, priority=0,
                    arrival=50.0)
    assert sched._order_key(req, 50.0) < sched._order_key(fresh, 50.0)


# ---------------------------------------------------------------------------
# Graceful degradation: TIMEOUT terminal states, never exceptions
# ---------------------------------------------------------------------------

def test_deadline_timeout_mid_decode_keeps_partial_output():
    e, _ = _engine(ServeConfig(max_seq_len=64, batch_size=1))
    sched = PriorityScheduler(e, clock=TickClock(0.1))
    sched.submit(Request(rid=0, prompt=np.ones(4, np.int32), max_new=50,
                         deadline_s=2.0))
    done = sched.run()                           # must NOT raise
    assert len(done) == 1
    r = done[0]
    assert r.status is RequestStatus.TIMEOUT
    assert 0 < len(r.generated) < 50             # partial output kept
    assert "deadline" in r.error
    assert sched.stats["timeouts"] == 1


def test_expired_deadline_shed_at_admission():
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1))
    sched = PriorityScheduler(e, clock=TickClock(0.1))
    sched.submit(Request(rid=0, prompt=np.ones(4, np.int32), max_new=4,
                         deadline_s=0.0))       # expired the moment it lands
    sched.submit(Request(rid=1, prompt=np.ones(4, np.int32), max_new=4))
    done = {r.rid: r for r in sched.run()}
    assert done[0].status is RequestStatus.TIMEOUT
    assert done[0].generated == [] and "shed" in done[0].error
    assert done[1].status is RequestStatus.OK    # queue kept draining
    assert sched.stats["shed"] == 1


def test_hopeless_deadline_shed_with_reason():
    """With a measured tick EMA, a deadline that cannot even see its first
    token is shed up front instead of burning prefill compute."""
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1))
    sched = PriorityScheduler(e, clock=TickClock(0.0, t0=1.0))
    sched._tick_ema = 10.0                       # 10 s/tick measured
    sched.submit(Request(rid=0, prompt=np.ones(4, np.int32), max_new=4,
                         deadline_s=5.0))        # first token eta ~ +20 s
    done = sched.run()
    assert done[0].status is RequestStatus.TIMEOUT
    assert "hopeless" in done[0].error and done[0].generated == []


# ---------------------------------------------------------------------------
# Overcommit + preemption (the ISSUE-6 acceptance soak test)
# ---------------------------------------------------------------------------

def _soak_scfg(overcommit: float) -> ServeConfig:
    # 3 requests x worst-case 4 blocks = 12 > pool of 9: the mix cannot be
    # admitted worst-case, but lazily each admission takes only 3 blocks
    # (2 prompt + 1 headroom), so at 1.5x all three run and collide on the
    # 4th block mid-decode -> preemption + warm re-admission.
    return ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=9, paged_attn="gather",
                       overcommit=overcommit)


def _soak_requests(rng) -> list:
    return [Request(rid=i, prompt=rng.integers(1, 64, 9).astype(np.int32),
                    max_new=20) for i in range(3)]


def test_overcommit_soak_completes_all_with_token_parity():
    """A request mix whose worst-case reservation (12 blocks) exceeds the
    pool (9) must complete every request via preemption + warm-list
    re-admission — none live-locked, per-request greedy tokens bitwise
    equal to the same mix run unconstrained."""
    e, sp = _engine(_soak_scfg(overcommit=1.5))
    assert e.worst_case_blocks(9, 20) == 4
    sched = PriorityScheduler(e)
    rng = np.random.default_rng(11)
    reqs = _soak_requests(rng)
    for r in reqs:
        sched.submit(r)
    done = {r.rid: r for r in sched.run()}
    assert len(done) == 3
    assert all(r.status is RequestStatus.OK and len(r.generated) == 20
               for r in done.values())
    assert sched.stats["preemptions"] >= 1       # the pool DID run dry
    assert sched.stats["readmissions"] >= 1
    assert sched.stats["readmission_hit_tokens"] > 0   # warm prefix re-hit
    # no leaks: every block claimable again, refcounts at zero
    assert e.pool.free_count == e.pool.num_blocks
    assert e.pool.live_refs == 0
    # parity vs the unconstrained engine, request by request
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=32, batch_size=1))
    for r in reqs:
        ref.reset()
        want = ref.generate(np.asarray(r.prompt)[None, :], r.max_new)[0]
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(want))


def test_overcommit_budget_gate_at_one_never_preempts():
    """overcommit=1.0: the admission budget keeps the sum of running
    worst cases within the pool, so preemption can never fire — the third
    request waits for a completion instead."""
    e, _ = _engine(_soak_scfg(overcommit=1.0))
    sched = PriorityScheduler(e)
    rng = np.random.default_rng(11)
    for r in _soak_requests(rng):
        sched.submit(r)
    done = sched.run()
    assert len(done) == 3
    assert all(r.status is RequestStatus.OK and len(r.generated) == 20
               for r in done)
    assert sched.stats["preemptions"] == 0
    assert e.pool.free_count == e.pool.num_blocks


# ---------------------------------------------------------------------------
# Warm-list prefix revival under eviction churn (ISSUE-6 satellite 3)
# ---------------------------------------------------------------------------

def test_preemption_churn_warm_revival_tail_only_reprefill():
    """Evict a slot mid-decode (deterministically, via the fault-injection
    seam — no pool pressure, so the warm blocks survive), re-admit it, and
    assert the re-admission is a prefix HIT that re-prefills only the
    generated tail, with bitwise token parity vs an uninterrupted run."""
    scfg = ServeConfig(max_seq_len=32, batch_size=1, kv_block_size=8,
                       kv_num_blocks=8, paged_attn="gather")
    e, sp = _engine(scfg)
    # admission is alloc call #1 (2 blocks: prompt + headroom); the decode
    # extension at position 16 is call #2 — fail exactly that one
    e.pool.fault_injector = lambda call, n: call == 2
    prompt = np.arange(1, 9, dtype=np.int32)     # 8 = exactly 1 full block
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=prompt.copy(), max_new=12))
    done = sched.run()
    assert len(done) == 1 and done[0].status is RequestStatus.OK
    assert len(done[0].generated) == 12
    assert done[0].preemptions == 1
    assert sched.stats["preemptions"] == 1
    assert e.pool.stats["faults_injected"] == 1
    # the re-admission hash-hit the warm prompt block: exactly the one full
    # block (8 tokens) revived, the 9-token generated tail re-prefilled
    assert e.pool.stats["warm_hit_blocks"] == 1
    assert e.pool.stats["hit_tokens"] == 8
    assert sched.stats["readmission_hit_tokens"] == 8
    # bitwise parity vs the uninterrupted run (same engine config, no fault)
    ref = Engine(CFG, sp, scfg)
    want = ref.generate(prompt[None, :], 12)[0]
    np.testing.assert_array_equal(np.asarray(done[0].generated),
                                  np.asarray(want))
    assert e.pool.free_count == e.pool.num_blocks


def test_pinning_after_max_preemptions_completes():
    """A request evicted max_preemptions times is pinned: admitted ahead of
    every lane and never re-picked as a victim — it completes instead of
    live-locking.  Faults on every extension alloc force repeat evictions."""
    scfg = ServeConfig(max_seq_len=48, batch_size=1, kv_block_size=8,
                       kv_num_blocks=8, paged_attn="gather",
                       max_preemptions=2)
    e, _ = _engine(scfg)
    # alloc ordinals: #1 admission (2 blocks, 16 positions), #2 the
    # extension at position 16 -> fault -> preemption 1; #3 re-admission
    # (covers 32 positions), #4 the extension at position 32 -> fault ->
    # preemption 2 (now pinned); #5 the final re-admission
    e.pool.fault_injector = lambda call, n: call in (2, 4)
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                         max_new=30))                 # 8 + 30 = 38 positions
    done = sched.run()
    assert done[0].status is RequestStatus.OK
    assert len(done[0].generated) == 30
    assert done[0].preemptions == 2
    assert sched._pinned(done[0])
    assert e.pool.stats["faults_injected"] == 2


# ---------------------------------------------------------------------------
# Evict-cost-aware victim ranking (ISSUE-7 satellite)
# ---------------------------------------------------------------------------

def test_victim_key_protects_invested_work():
    """At equal lane and deadline, the victim (max key wins) is the request
    with the FEWEST generated tokens — every generated token is re-prefill
    cost at re-admission, so a long-running request outranks a fresh one.
    Lane and deadline still dominate the cost term."""
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=1))
    sched = PriorityScheduler(e)
    now = 0.0                            # no queue wait: lanes == priorities

    def req(rid, gen, priority=1, deadline_s=None):
        r = Request(rid=rid, prompt=np.ones(4, np.int32), max_new=30,
                    priority=priority, deadline_s=deadline_s, arrival=0.0)
        r.generated = [1] * gen
        return r

    old, fresh = req(0, gen=10), req(1, gen=1)
    assert sched._victim_key(fresh, now) > sched._victim_key(old, now)
    # deadline outranks invested work: the further deadline is evicted even
    # though it is the more expensive re-prefill
    far = req(2, gen=10, deadline_s=100.0)
    near = req(3, gen=0, deadline_s=50.0)
    assert sched._victim_key(far, now) > sched._victim_key(near, now)
    # lane outranks both
    low = req(4, gen=20, priority=2)
    assert sched._victim_key(low, now) > sched._victim_key(fresh, now)


# ---------------------------------------------------------------------------
# Prefill-token budget: giant prompts span ticks without stalling decode
# (ISSUE-7 satellite; fake-clock regression)
# ---------------------------------------------------------------------------

def test_prefill_budget_spans_ticks_without_stalling_decode():
    """With ``max_prefill_tokens_per_tick=8``, a 32-token prompt becomes a
    4-tick resumable prefill job — and an already-running request keeps
    decoding exactly one token per tick throughout (the lane-0 latency the
    budget exists to protect), with bitwise parity for both."""
    scfg = ServeConfig(max_seq_len=64, batch_size=2, kv_block_size=8,
                       kv_num_blocks=12, prefill_chunk=8, paged_attn="gather",
                       max_prefill_tokens_per_tick=8, audit_interval=1)
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e, clock=TickClock(0.0))
    rng = np.random.default_rng(21)
    short = rng.integers(1, 64, 4).astype(np.int32)
    giant = rng.integers(1, 64, 32).astype(np.int32)
    a = Request(rid=0, prompt=short, max_new=8)
    b = Request(rid=1, prompt=giant, max_new=4)
    finished: list = []
    sched.submit(a)
    sched.tick(finished)                 # 4-token prompt fits the budget
    assert len(a.generated) == 2         # prefill token + one decode
    sched.submit(b)
    for expect_a in (3, 4, 5, 6):        # the giant spans ticks 2..5
        sched.tick(finished)
        assert len(a.generated) == expect_a      # decode NEVER stalled
        if expect_a < 6:
            assert list(sched._prefilling) == [1]    # job parked on slot 1
    assert not sched._prefilling         # 32 = 4 ticks x 8-token budget
    assert len(b.generated) == 2         # went live on tick 5 + one decode
    while not sched.idle:
        sched.tick(finished)
    done = {r.rid: r for r in finished}
    assert done[0].status is RequestStatus.OK
    assert done[1].status is RequestStatus.OK
    assert sched.stats["preemptions"] == 0
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=64, batch_size=1,
                                      prefill_chunk=8))
    for r in (a, b):
        ref.reset()
        want = ref.generate(np.asarray(r.prompt)[None, :], r.max_new)[0]
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(want))
    assert e.pool.free_count == e.pool.num_blocks
    assert e.pool.live_refs == 0


# ---------------------------------------------------------------------------
# AsyncFrontend: streaming, drain, serve loop (wait_for-guarded)
# ---------------------------------------------------------------------------

def test_async_drain_streams_tokens():
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=2))
    fe = AsyncFrontend(e)
    streamed: dict[int, list] = {}

    def on_token(req, tok):
        streamed.setdefault(req.rid, []).append(tok)

    async def go():
        rng = np.random.default_rng(5)
        reqs = [fe.submit(rng.integers(1, 64, 4 + i).astype(np.int32),
                          max_new=3, on_token=on_token) for i in range(3)]
        drained = await fe.drain()
        results = [await fe.result(r) for r in reqs]
        return reqs, drained, results

    reqs, drained, results = _run_async(go())
    assert len(drained) == 3
    for r in reqs:
        assert r.status is RequestStatus.OK and len(r.generated) == 3
        assert streamed[r.rid] == r.generated    # every token streamed live
    assert results == reqs


def test_async_submit_rejection_settles_immediately():
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=2))
    fe = AsyncFrontend(e)

    async def go():
        bad = fe.submit(np.zeros((0,), np.int32), max_new=2)
        assert bad.done                          # settled without a tick
        return await fe.result(bad)

    bad = _run_async(go())
    assert bad.status is RequestStatus.REJECTED_VALIDATION


def test_async_serve_loop_start_stop():
    e, _ = _engine(ServeConfig(max_seq_len=32, batch_size=2))
    fe = AsyncFrontend(e)

    async def go():
        server = asyncio.create_task(fe.serve())
        req = fe.submit(np.ones(4, np.int32), max_new=3, priority=1)
        await fe.result(req)
        late = fe.submit(np.ones(5, np.int32), max_new=2)   # wakes the loop
        await fe.result(late)
        fe.stop()
        await server
        return req, late

    req, late = _run_async(go())
    assert req.status is RequestStatus.OK and len(req.generated) == 3
    assert late.status is RequestStatus.OK and len(late.generated) == 2
