"""Paged KV cache: allocator semantics, pool-exhaustion deferral, refcount
hygiene, copy-on-write divergence, and paged parity beyond the serve config
(sliding-window ring buffers, MLA latent caches, hybrid SSM stacks)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve import paging
from repro.serve.engine import BatchScheduler, Engine, Request

KEY = jax.random.PRNGKey(0)

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)


def _engine(scfg: ServeConfig, cfg=CFG):
    params = tfm.init_params(cfg, KEY)
    return Engine(cfg, tfm.serve_params(params, cfg), scfg), \
        tfm.serve_params(params, cfg)


# ---------------------------------------------------------------------------
# BlockPool (host allocator) unit semantics
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_refcount():
    pool = paging.BlockPool(4, 8)
    a, b = pool.alloc(2)
    assert pool.free_count == 2 and pool.live_refs == 2
    pool.free(a)
    assert pool.free_count == 3
    with pytest.raises(ValueError):
        pool.free(a)                         # double free
    with pytest.raises(paging.BlockPoolExhausted):
        pool.alloc(4)                        # only 3 free — no partial alloc
    assert pool.free_count == 3              # failed alloc took nothing
    pool.free(b)
    assert pool.free_count == 4 and pool.live_refs == 0


def test_block_pool_prefix_sharing_and_eviction():
    pool = paging.BlockPool(4, 2)
    toks = np.arange(8, dtype=np.int32)
    hashes = paging.block_hashes(toks, 2)
    assert len(hashes) == 4
    # chained: equal prefixes agree, divergence breaks the chain
    other = paging.block_hashes(
        np.concatenate([toks[:4], toks[4:] + 1]), 2)
    assert other[:2] == hashes[:2] and other[2] != hashes[2]
    (bid,) = pool.alloc(1)
    pool.register(bid, hashes[0])
    assert pool.match_prefix(hashes) == [bid]
    hits = pool.take_prefix(hashes)          # incref
    assert hits == [bid] and pool.live_refs == 2
    pool.free(bid)                           # original holder evicts
    assert pool.match_prefix(hashes) == [bid]   # still resident (our ref)
    pool.free(bid)                           # last ref -> WARM, not evicted:
    assert pool.match_prefix(hashes) == [bid]   # matchable until reclaimed
    assert pool.is_warm(bid) and pool.live_refs == 0
    assert pool.free_count == 4              # warm blocks are claimable


def test_block_pool_warm_hit_after_evict_and_lru_reclaim():
    """ROADMAP follow-on (d): a prefix hit must not require a resident
    holder — freed registered blocks stay warm (matchable, revivable at
    zero prefill cost) until alloc reclaims them, oldest-freed first."""
    pool = paging.BlockPool(4, 2)
    toks = np.arange(8, dtype=np.int32)
    hashes = paging.block_hashes(toks, 2)
    b0, b1 = pool.alloc(2)
    pool.register(b0, hashes[0])
    pool.register(b1, hashes[1])
    pool.free(b0)
    pool.free(b1)
    assert pool.warm_count == 2 and pool.free_count == 4
    # hit-after-evict: both blocks revive with their contents intact
    hits = pool.take_prefix(hashes)
    assert hits == [b0, b1]
    assert pool.stats["warm_hit_blocks"] == 2 and pool.warm_count == 0
    assert pool.live_refs == 2
    pool.free(b0), pool.free(b1)             # back to warm (b0 older)
    # reclaim-under-pressure: free list (2 blocks) drains first, then the
    # warm blocks are cannibalized LRU-first and their hashes evicted
    got = pool.alloc(3)
    assert pool.stats["warm_reclaims"] == 1
    assert b0 in got and b1 not in got       # b0 was freed first -> LRU
    assert pool.match_prefix(hashes) == []   # chain broken at block 0
    assert pool.match_prefix(hashes[1:]) == [b1]   # b1 itself is still warm
    (b_last,) = pool.alloc(1)                # reclaims b1 too
    assert b_last == b1 and pool.stats["warm_reclaims"] == 2
    with pytest.raises(paging.BlockPoolExhausted):
        pool.alloc(1)
    for b in got + [b_last]:
        pool.free(b)
    assert pool.free_count == 4 and pool.warm_count == 0


def test_block_pool_fault_injection_fires_once():
    """The injector fails exactly the listed alloc ordinal, takes no
    blocks, and the counter moves past it (a retry succeeds)."""
    pool = paging.BlockPool(4, 8, fault_injector=lambda call, n: call == 2)
    a = pool.alloc(2)                            # call 1: fine
    with pytest.raises(paging.BlockPoolExhausted):
        pool.alloc(1)                            # call 2: injected fault
    assert pool.stats["faults_injected"] == 1
    assert pool.free_count == 2                  # failed call took nothing
    b = pool.alloc(2)                            # call 3: fires only once
    assert pool.free_count == 0 and pool.live_refs == 4
    for bid in a + b:
        pool.free(bid)


def test_env_fault_injector_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_ALLOC", raising=False)
    assert paging.env_fault_injector() is None
    monkeypatch.setenv("REPRO_FAULT_ALLOC", "")
    assert paging.env_fault_injector() is None
    monkeypatch.setenv("REPRO_FAULT_ALLOC", "2,5")
    inj = paging.env_fault_injector()
    assert inj(2, 1) and inj(5, 3) and not inj(1, 1) and not inj(3, 2)
    # a fresh pool picks the env injector up automatically
    pool = paging.BlockPool(4, 8)
    pool.alloc(1)
    with pytest.raises(paging.BlockPoolExhausted):
        pool.alloc(1)
    assert pool.stats["faults_injected"] == 1
    monkeypatch.setenv("REPRO_FAULT_ALLOC", "nope")
    with pytest.raises(ValueError):
        paging.env_fault_injector()


def test_block_pool_ensure_exclusive_cow():
    pool = paging.BlockPool(4, 2)
    (bid,) = pool.alloc(1)
    same, copied = pool.ensure_exclusive(bid)
    assert same == bid and not copied        # refcount 1: no copy
    pool._ref[bid] += 1                      # simulate a second holder
    new, copied = pool.ensure_exclusive(bid)
    assert copied and new != bid
    assert pool._ref[bid] == 1 and pool._ref[new] == 1
    assert pool.stats["cow_copies"] == 1


def test_paged_layout_geometry_and_validation():
    scfg = ServeConfig(max_seq_len=64, batch_size=2, kv_block_size=8)
    lay = paging.paged_layout(CFG, scfg)
    assert lay.mb_full == 8 and lay.mb_ring == 0
    assert lay.num_blocks == 2 * 8 and lay.trash_block == 16
    assert lay.blocks_for(1) == 1 and lay.blocks_for(64) == 8
    assert paging.paged_layout(CFG, ServeConfig(max_seq_len=64)) is None
    rg = get_config("recurrentgemma-2b").reduced()     # window = 16
    lay_rg = paging.paged_layout(rg, scfg)
    assert lay_rg.mb_full == 0 and lay_rg.mb_ring == 2
    assert lay_rg.ring_slots == 16
    with pytest.raises(ValueError):                    # 5 doesn't divide 16
        paging.paged_layout(rg, dataclasses.replace(scfg, kv_block_size=5))
    assert paging.prefix_sharing_supported(CFG)
    assert not paging.prefix_sharing_supported(rg)


# ---------------------------------------------------------------------------
# Engine/scheduler edge cases (PR 3 satellite test coverage)
# ---------------------------------------------------------------------------

def test_pool_exhaustion_defers_admission_and_frees_all_blocks():
    """A pool too small for all requests at once must DEFER admissions (not
    crash) and complete every request as evictions free blocks; afterwards
    every block is back on the free list (no leaks, refcounts at zero)."""
    scfg = ServeConfig(max_seq_len=64, batch_size=2, kv_block_size=8,
                       kv_num_blocks=4)      # 1 slot's worth at a time
    e, _ = _engine(scfg)
    sched = BatchScheduler(e)
    rng = np.random.default_rng(1)
    for i in range(4):
        sched.submit(Request(rid=i,
                             prompt=rng.integers(1, 64, 17).astype(np.int32),
                             max_new=4))     # 17+4 tokens -> 3 blocks each
    done = sched.run()
    assert len(done) == 4
    assert all(r.done and not r.error and len(r.generated) == 4
               for r in done)
    assert e.pool.free_count == e.pool.num_blocks
    assert e.pool.live_refs == 0


def test_request_larger_than_pool_fails_at_submit():
    scfg = ServeConfig(max_seq_len=64, batch_size=2, kv_block_size=8,
                       kv_num_blocks=2)
    e, _ = _engine(scfg)
    sched = BatchScheduler(e)
    sched.submit(Request(rid=0, prompt=np.ones(30, np.int32), max_new=4))
    sched.submit(Request(rid=1, prompt=np.ones(9, np.int32), max_new=3))
    done = sched.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].error and "blocks" in by_rid[0].error
    assert not by_rid[1].error and len(by_rid[1].generated) == 3
    assert e.pool.free_count == e.pool.num_blocks


def test_cow_divergence_after_shared_prefix():
    """Copy-on-write coverage: prompts whose length is an exact block
    multiple share ALL their blocks, so recomputing the final prompt token
    must COW the last shared block; requests diverging after the shared
    prefix must each decode their solo-generation tokens.  (Gather mode —
    the subject is allocator/COW logic; kernel-mode COW runs in
    test_paged_attn's scheduler test and the slow tier.)"""
    scfg = ServeConfig(max_seq_len=64, batch_size=3, kv_block_size=8,
                       paged_attn="gather")
    e, sp = _engine(scfg)
    rng = np.random.default_rng(2)
    prefix = rng.integers(1, 64, 16).astype(np.int32)   # 2 full blocks
    tail = rng.integers(1, 64, 5).astype(np.int32)
    reqs = [Request(rid=0, prompt=prefix.copy(), max_new=6),
            Request(rid=1, prompt=prefix.copy(), max_new=6),   # COW case
            Request(rid=2, prompt=np.concatenate([prefix, tail]),
                    max_new=6)]                                # divergence
    sched = BatchScheduler(e)
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert len(done) == 3
    assert e.pool.stats["cow_copies"] >= 1
    assert e.pool.stats["hit_tokens"] >= 2 * len(prefix)
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=64, batch_size=1))
    for r in sorted(done, key=lambda r: r.rid):
        ref.reset()
        want = ref.generate(jnp.asarray(r.prompt)[None, :], r.max_new)[0]
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(want))
    assert e.pool.free_count == e.pool.num_blocks


def test_prefill_into_reserve_zero_gets_decode_headroom():
    """Direct engine use: prefill_into with the default reserve=0 must
    still leave one block of decode headroom past the prompt, so a
    subsequent decode step never writes the trash block (regression:
    exact-block-multiple prompts used to scatter the next token's KV into
    the trash block and silently corrupt logits).  Gather mode: the compare
    against the dense engine is bitwise."""
    scfg = ServeConfig(max_seq_len=64, batch_size=1, kv_block_size=8,
                       paged_attn="gather")
    e, sp = _engine(scfg)
    e_d = Engine(CFG, sp, ServeConfig(max_seq_len=64, batch_size=1))
    prompt = np.arange(1, 17, dtype=np.int32)       # 16 = 2 full blocks
    lg_p = e.prefill_into(0, prompt)                # reserve=0
    lg_d = e_d.prefill_into(0, prompt)
    np.testing.assert_array_equal(np.asarray(lg_p), np.asarray(lg_d))
    assert e._full_count[0] == 3                    # 2 prompt + 1 headroom
    t = jnp.argmax(lg_p)[None, None].astype(jnp.int32)
    for _ in range(3):                              # decode inside headroom
        lg_p, e.cache = e._decode(e.params, e.cache, t)
        lg_d, e_d.cache = e_d._decode(e_d.params, e_d.cache, t)
        np.testing.assert_array_equal(np.asarray(lg_p), np.asarray(lg_d))
        t = jnp.argmax(lg_p, -1)[:, None].astype(jnp.int32)


def test_warm_block_hit_survives_full_eviction():
    """Engine-level ROADMAP (d): after EVERY holder of a shared prefix is
    evicted, a new admission of the same prompt must still hash-hit the
    (now warm) blocks and produce logits identical to the cold admission
    (gather mode: the bitwise bar).  Pool of 8 blocks so the reclaim-under-
    pressure leg below actually drains the free list."""
    scfg = ServeConfig(max_seq_len=64, batch_size=2, kv_block_size=8,
                       kv_num_blocks=8, paged_attn="gather")
    e, _ = _engine(scfg)
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, 64, 21).astype(np.int32)   # 2 full blocks + 5
    cold = np.asarray(e.prefill_into(0, prompt, reserve=2))
    e.free_slot(0)                       # no resident holder remains
    assert e.pool.live_refs == 0 and e.pool.warm_count == 2
    warm = np.asarray(e.prefill_into(1, prompt, reserve=2))
    assert e.pool.stats["warm_hit_blocks"] == 2
    assert e.pool.stats["hit_tokens"] == 16
    np.testing.assert_array_equal(cold, warm)
    # under pressure warm blocks are ordinary capacity: the SAME engine's
    # next big admission reclaims them (oldest first), evicting the hash —
    # a later lookup of the prefix is then a clean miss, not a hang.
    # (Allocator-level LRU/reclaim order is unit-tested above.)
    e.free_slot(1)
    assert e.pool.warm_count == 2
    big = rng.integers(1, 64, 57).astype(np.int32)  # 8 blocks: whole pool
    e.prefill_into(0, big, reserve=0)      # 8-block pool: 6 free + 2 warm
    assert e.pool.stats["warm_reclaims"] >= 1
    e.free_slot(0)
    hit_before = e.pool.stats["hit_tokens"]
    e.prefill_into(1, prompt, reserve=2)
    assert e.pool.stats["hit_tokens"] == hit_before     # clean miss


def test_warm_cow_hit_readmits_when_pool_exactly_full():
    """Regression (PR-4 warm list): a request whose worst-case demand
    exactly fills the pool must stay re-admittable after its blocks go
    warm.  An exact-block-multiple prompt re-hits its own warm blocks with
    cow=True, but the warm-revived block has refcount 1 and never actually
    copies — charging the COW block anyway made ``can_admit`` return None
    forever and the scheduler raise 'stalled'."""
    scfg = ServeConfig(max_seq_len=32, batch_size=1, kv_block_size=8,
                       kv_num_blocks=4, paged_attn="gather")
    e, _ = _engine(scfg)
    prompt = np.arange(1, 17, dtype=np.int32)      # 16 = 2 full blocks
    sched = BatchScheduler(e)
    sched.submit(Request(rid=0, prompt=prompt.copy(), max_new=16))
    done = sched.run()                             # cold: worst = 4 == pool
    assert len(done) == 1 and not done[0].error
    assert e.pool.warm_count == 2                  # registered blocks warm
    sched2 = BatchScheduler(e)                     # re-admit the same prompt
    sched2.submit(Request(rid=1, prompt=prompt.copy(), max_new=16))
    done2 = sched2.run()                           # must not stall
    assert len(done2) == 1 and not done2[0].error
    np.testing.assert_array_equal(done2[0].generated, done[0].generated)
    assert e.pool.free_count == e.pool.num_blocks


def test_shared_prefix_admission_skips_prefill_compute():
    """A prefix hit must admit by mapping blocks, only computing the tail:
    observable as pool stats hits AND bitwise-identical logits to a cold
    admission of the same prompt."""
    scfg = ServeConfig(max_seq_len=64, batch_size=2, kv_block_size=8,
                       paged_attn="gather")
    e, _ = _engine(scfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, 64, 21).astype(np.int32)   # 2 full blocks + 5
    cold = np.asarray(e.prefill_into(0, prompt, reserve=2))
    assert e.pool.stats["hit_tokens"] == 0
    warm = np.asarray(e.prefill_into(1, prompt, reserve=2))
    assert e.pool.stats["hit_tokens"] == 16             # both full blocks
    np.testing.assert_array_equal(cold, warm)
    # the shared blocks are the SAME physical ids in both tables
    np.testing.assert_array_equal(e._tables[0][:2], e._tables[1][:2])
    assert e._tables[0][2] != e._tables[1][2]           # private tails


# ---------------------------------------------------------------------------
# Warm-list edge cases under faults (ISSUE-7 satellite; auditor-verified)
# ---------------------------------------------------------------------------

def test_warm_revival_after_partial_lru_reclaim_stays_consistent():
    """Reclaim-under-pressure cannibalizes only the OLDEST warm blocks;
    the surviving prefix chain must still revive, and the pool must pass
    a full invariant audit at every step of the churn."""
    from repro.serve import audit
    pool = paging.BlockPool(4, 2)
    toks = np.arange(8, dtype=np.int32)
    hashes = paging.block_hashes(toks, 2)
    b0, b1, b2 = pool.alloc(3)
    for b, h in zip((b0, b1, b2), hashes):
        pool.register(b, h)
    audit.audit_pool(pool, [[b0, b1, b2]])
    # free NEWEST-first so the LRU (oldest-freed) victims are the chain
    # TAIL — a partial reclaim must leave the chain HEAD matchable
    pool.free(b2), pool.free(b1), pool.free(b0)
    audit.audit_pool(pool, [])
    got = pool.alloc(2)                     # 1 free block + reclaims b2
    assert pool.stats["warm_reclaims"] == 1
    assert b2 in got and b0 not in got and b1 not in got
    audit.audit_pool(pool, [got])
    hits = pool.take_prefix(hashes)         # revival across the reclaim
    assert hits == [b0, b1]                 # surviving prefix, chain intact
    assert pool.stats["warm_hit_blocks"] == 2
    audit.audit_pool(pool, [got, hits])
    for b in got + hits:
        pool.free(b)
    audit.audit_pool(pool, [])
    assert pool.free_count == pool.num_blocks


def test_alloc_fault_during_cow_divergence_keeps_pool_consistent():
    """An injected allocator failure at the exact COW-divergence alloc
    (re-computing the final token of an exact-block-multiple shared
    prompt) must roll the admission back with refcounts and the hash
    registry consistent — proven by the auditor running EVERY tick —
    then succeed on the retry with bitwise token parity."""
    from repro.serve import audit
    from repro.serve.frontend import PriorityScheduler
    scfg = ServeConfig(max_seq_len=32, batch_size=2, kv_block_size=8,
                       kv_num_blocks=8, paged_attn="gather",
                       fault_plan="alloc@2", audit_interval=1)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(23)
    prompt = rng.integers(1, 64, 16).astype(np.int32)   # 2 full blocks
    sched = PriorityScheduler(e)
    # same prompt twice: rid 1's admission take_prefix-hits rid 0's
    # resident blocks (ref 2) and must COW the last one — alloc call #1
    # is rid 0's admission, call #2 is exactly that COW copy
    for rid in (0, 1):
        sched.submit(Request(rid=rid, prompt=prompt.copy(), max_new=8))
    done = {r.rid: r for r in sched.run()}
    assert len(done) == 2
    assert e.pool.stats["faults_injected"] == 1
    assert sched.fault_plan.fired["alloc"] == 1
    assert e.pool.stats["cow_copies"] >= 1              # the retry did COW
    assert e.pool.stats["hit_tokens"] >= 16             # ... after a re-hit
    for rid in (0, 1):                      # same prompt -> same greedy toks
        assert not done[rid].error and len(done[rid].generated) == 8
    assert done[0].generated == done[1].generated
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=32, batch_size=1))
    want = ref.generate(prompt[None, :], 8)[0]
    np.testing.assert_array_equal(np.asarray(done[0].generated),
                                  np.asarray(want))
    assert e.pool.free_count == e.pool.num_blocks       # nothing leaked
    assert e.pool.live_refs == 0
    audit.audit_scheduler(sched)


# ---------------------------------------------------------------------------
# Paged parity beyond the serve config: ring buffers, MLA, hybrid SSM
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch,block", [("recurrentgemma-2b", 8),
                                        ("deepseek-v2-lite-16b", 4),
                                        ("mamba2-780m", 8)])
def test_paged_matches_dense_across_families(arch, block):
    """Sliding-window ring buffers and MLA latent caches read/write through
    the block table; SSM recurrent state stays per-slot (mamba2 is the
    degenerate all-SSM case: an empty table and a zero-block pool must
    still serve).  Greedy decodes must match the dense layout token-for-
    token (reduced shapes: XLA dot lowering may reassociate, so token
    equality + tight logits allclose is the bar here; the bitwise bar
    lives on the serve config in test_serve)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), vocab_size=64,
                              capacity_factor=64.0)
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    scfg = ServeConfig(max_seq_len=32, batch_size=2)
    e_dense = Engine(cfg, sp, scfg)
    e_paged = Engine(cfg, sp, dataclasses.replace(scfg, kv_block_size=block))
    assert e_paged.paged
    prompts = jax.random.randint(jax.random.PRNGKey(8), (2, 20), 0,
                                 cfg.vocab_size)        # 20 > window=16: wrap
    lg_d = np.asarray(e_dense.prefill(prompts, start=0))
    lg_p = np.asarray(e_paged.prefill(prompts, start=0))
    np.testing.assert_allclose(lg_p, lg_d, rtol=1e-5, atol=1e-5)
    e_dense.reset(), e_paged.reset()
    t_d = e_dense.generate(prompts, max_new=8)
    t_p = e_paged.generate(prompts, max_new=8)
    np.testing.assert_array_equal(t_d, t_p)


def test_paged_scheduler_mixed_lengths_match_per_request():
    """Continuous batching over the paged cache: mixed-length traffic must
    decode per-request-identical tokens (the PR-2 scheduler discipline,
    now with block tables)."""
    params = tfm.init_params(CFG, KEY)
    sp = tfm.serve_params(params, CFG)
    e = Engine(CFG, sp, ServeConfig(max_seq_len=32, batch_size=2,
                                    prefill_chunk=4, kv_block_size=4))
    sched = BatchScheduler(e)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, CFG.vocab_size, n).astype(np.int32)
               for n in (3, 9, 5, 8)]        # 8: exact block multiple
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    done = sched.run()
    assert len(done) == 4
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=32, batch_size=1,
                                      prefill_chunk=4))
    for r in sorted(done, key=lambda r: r.rid):
        ref.reset()
        want = ref.generate(jnp.asarray(r.prompt)[None, :], r.max_new)[0]
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(want))
    assert e.pool.free_count == e.pool.num_blocks
