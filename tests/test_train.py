"""Training loop: convergence, checkpoint/restart, fault tolerance, elastic."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config
from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt
from repro.train import data as data_lib
from repro.train import optimizer as opt
from repro.train.fault import FaultManager, Heartbeat, StragglerPolicy
from repro.train.loop import train_state_init, train_step

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64)
TCFG = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=200,
                   grad_clip=1.0)


def _batch(step, b=8, s=32):
    return jax.tree.map(jnp.asarray,
                        data_lib.synthetic_batch(CFG, b, s, step))


def test_loss_decreases():
    """QAT (STE-ternary) training reduces CE on the structured stream."""
    state = train_state_init(CFG, TCFG, jax.random.PRNGKey(0))
    step = jax.jit(lambda st, b: train_step(st, b, cfg=CFG, tcfg=TCFG))
    losses = []
    for i in range(80):
        state, m = step(state, _batch(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert min(losses[-10:]) < losses[0] - 0.25, losses[::16]


def test_microbatch_grad_accum_matches_full_batch():
    state = train_state_init(CFG, TCFG, jax.random.PRNGKey(0))
    tc1 = dataclasses.replace(TCFG, microbatches=1)
    tc2 = dataclasses.replace(TCFG, microbatches=2)
    b = _batch(0, b=4)
    s1, m1 = jax.jit(lambda st, bb: train_step(st, bb, cfg=CFG, tcfg=tc1))(
        state, b)
    state2 = train_state_init(CFG, TCFG, jax.random.PRNGKey(0))
    s2, m2 = jax.jit(lambda st, bb: train_step(st, bb, cfg=CFG, tcfg=tc2))(
        state2, b)
    # same data, same step: params should agree to fp tolerance
    for p1, p2 in zip(jax.tree.leaves(s1["params"]),
                      jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(p1, np.float32),
                                   np.asarray(p2, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_lion_optimizer_runs():
    tc = dataclasses.replace(TCFG, optimizer="lion", learning_rate=1e-3)
    state = train_state_init(CFG, tc, jax.random.PRNGKey(0))
    step = jax.jit(lambda st, b: train_step(st, b, cfg=CFG, tcfg=tc))
    losses = []
    for i in range(20):
        state, m = step(state, _batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_checkpoint_roundtrip_bitexact():
    state = train_state_init(CFG, TCFG, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, state)
        assert ckpt.latest_step(d) == 7
        restored = ckpt.restore(d, 7, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption_and_falls_back():
    state = train_state_init(CFG, TCFG, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state)
        ckpt.save(d, 2, state)
        # corrupt step 2
        path = os.path.join(d, "step_00000002", "arrays.npz")
        with open(path, "r+b") as f:
            f.seek(100)
            f.write(b"\xde\xad\xbe\xef")
        with pytest.raises(IOError):
            ckpt.restore(d, 2, state)
        fm = FaultManager(d)
        step, restored = fm.restore_latest(state)
        assert step == 1 and restored is not None


def test_fault_manager_resumes_after_injected_failure():
    with tempfile.TemporaryDirectory() as d:
        fm = FaultManager(d, checkpoint_every=5, max_restarts=3)
        state = train_state_init(CFG, TCFG, jax.random.PRNGKey(0))
        stepper = jax.jit(lambda st, b: train_step(st, b, cfg=CFG, tcfg=TCFG))
        calls = {"n": 0}

        def flaky(st, b):
            calls["n"] += 1
            if calls["n"] == 12:                   # injected node failure
                raise RuntimeError("simulated preemption")
            return stepper(st, b)

        out = fm.run(state, flaky, _batch, total_steps=20, state_like=state)
        assert fm.restarts == 1
        assert out is not None


def test_elastic_restore_across_shardings():
    """Checkpoint written under one sharding restores under another."""
    state = train_state_init(CFG, TCFG, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, state)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.parallel import sharding as shd
        specs = shd.param_pspecs(state["params"], mesh)
        shards = shd.shardings({"params": specs,
                                "opt": opt.OptState(
                                    step=jax.sharding.PartitionSpec(),
                                    mu=specs, nu=specs)}, mesh)
        restored = ckpt.restore(d, 3, state, shardings_tree=shards)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_learnable():
    b1 = data_lib.synthetic_batch(CFG, 4, 16, 5)
    b2 = data_lib.synthetic_batch(CFG, 4, 16, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data_lib.synthetic_batch(CFG, 4, 16, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_heartbeat_and_straggler_detection():
    hb = Heartbeat(timeout_s=10.0)
    hb.beat(0, t=100.0)
    hb.beat(1, t=95.0)
    assert hb.dead_hosts(now=106.0) == [1]
    sp = StragglerPolicy(factor=1.5, window=4)
    for _ in range(4):
        sp.record(0, 1.0)
        sp.record(1, 1.0)
        sp.record(2, 2.5)
    assert sp.stragglers() == [2]


def test_lr_schedule_shape():
    tc = dataclasses.replace(TCFG, warmup_steps=10, total_steps=100,
                             learning_rate=1.0)
    lrs = [float(opt.lr_schedule(tc, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < lrs[2] and lrs[4] == pytest.approx(0.1, rel=1e-2)
