"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bin_matrix, pack2bit, preprocess_binary,
                        preprocess_ternary, preprocess_ternary_direct,
                        random_ternary, random_binary, tern_matrix)
from repro.kernels import (rsr_matmul_kernel, rsr_onehot_matmul,
                           ternary_dequant_matmul, ternary_matmul_kernel)
from repro.kernels.ref import rsr_onehot_ref, ternary_dequant_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("n,m,k,batch", [
    (256, 64, 4, 8),      # exact tile multiples
    (512, 96, 6, 8),
    (256, 128, 8, 16),    # P=256 one-hot
    (512, 40, 5, 8),      # ternary-direct friendly k
])
def test_rsr_onehot_kernel_vs_ref_binary(n, m, k, batch):
    b = random_binary(jax.random.fold_in(KEY, n + m), (n, m))
    idx = preprocess_binary(b, k)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (batch, n))
    pat = bin_matrix(k)
    got = rsr_onehot_matmul(x, idx.codes, pat, tile_b=8,
                            tile_blk=idx.num_blocks, tile_n=256)
    want = rsr_onehot_ref(x, idx.codes, pat)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rsr_kernel_dtypes(dtype):
    a = random_ternary(jax.random.fold_in(KEY, 3), (256, 60))
    idx = preprocess_ternary_direct(a, 5)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (4, 256)).astype(dtype)
    got = rsr_matmul_kernel(x, idx)
    want = x.astype(jnp.float32) @ a.astype(jnp.float32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("mode", ["fused", "two_pass", "direct"])
def test_rsr_kernel_ternary_modes(mode):
    a = random_ternary(jax.random.fold_in(KEY, 9), (300, 70))
    x = jax.random.normal(jax.random.fold_in(KEY, 10), (3, 300))
    want = x @ a.astype(jnp.float32)
    if mode == "direct":
        idx = preprocess_ternary_direct(a, 5)
        got = rsr_matmul_kernel(x, idx)
    else:
        idx = preprocess_ternary(a, 6)
        got = rsr_matmul_kernel(x, idx, fused_ternary=(mode == "fused"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rsr_kernel_scale_and_bias_semantics():
    a = random_ternary(jax.random.fold_in(KEY, 11), (128, 48))
    idx = preprocess_ternary_direct(a, 5)
    x = jax.random.normal(jax.random.fold_in(KEY, 12), (2, 128))
    got = rsr_matmul_kernel(x, idx, scale=jnp.float32(0.25))
    want = 0.25 * (x @ a.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,m,batch", [(256, 128, 8), (512, 256, 4),
                                       (260, 77, 3)])
def test_ternary_dequant_kernel_vs_ref(n, m, batch):
    n_pad = -(-n // 4) * 4
    a = random_ternary(jax.random.fold_in(KEY, n * m), (n_pad, m))
    packed = pack2bit(a)
    x = jax.random.normal(jax.random.fold_in(KEY, 13), (batch, n_pad))
    got = ternary_matmul_kernel(x, packed, m)
    want = ternary_dequant_ref(x, packed)[:, :m]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dequant_kernel_direct_tiles():
    a = random_ternary(jax.random.fold_in(KEY, 21), (512, 256))
    x = jax.random.normal(jax.random.fold_in(KEY, 22), (8, 512))
    got = ternary_dequant_matmul(x, pack2bit(a), tile_b=8, tile_m=128,
                                 tile_n=256)
    np.testing.assert_allclose(got, x @ a.astype(jnp.float32), rtol=1e-4,
                               atol=1e-4)


def test_kernel_matches_core_onehot_impl():
    """Kernel == core rsr one-hot impl == segments impl (same math)."""
    from repro.core import rsr_matmul_ternary_direct
    a = random_ternary(jax.random.fold_in(KEY, 31), (256, 55))
    idx = preprocess_ternary_direct(a, 5)
    x = jax.random.normal(jax.random.fold_in(KEY, 32), (2, 256))
    k_out = rsr_matmul_kernel(x, idx)
    c_out = rsr_matmul_ternary_direct(x, idx, impl="onehot")
    s_out = rsr_matmul_ternary_direct(x, idx, impl="segments")
    np.testing.assert_allclose(k_out, c_out, rtol=1e-5, atol=1e-5)
    # segments accumulates in a different (prefix-sum) order than the kernel's
    # bucketed fp32 adds — same math, 1e-4 is the honest fp32 tolerance.
    np.testing.assert_allclose(k_out, s_out, rtol=1e-4, atol=1e-4)
