"""Durable serve plane: checkpoint codec, write-ahead journal, recovery.

Three layers under test, bottom up:

* the record/array codecs and :class:`durability.CheckpointStore` —
  framing, CRC rejection, atomic publish, retention, journal epochs —
  exercised directly on bytes, including PARAMETRIZED truncation of a
  valid checkpoint at every record boundary and mid-record;
* the scheduler policy — JSON-deep mutation-isolated ``snapshot()``,
  tick/wall-clock periodic checkpoints, env overrides, write-ahead
  journaling of submit/terminal/preempt;
* recovery — ``durability.recover_scheduler`` /
  ``AsyncFrontend.recover``: newest-valid fallback ladder, journal-tail
  replay with verbatim terminal settlement, fingerprint refusal, the
  S1-S4 snapshot audit, and crash → recover → drain bitwise token
  parity on a fresh engine (with disk faults torn/flip/fsync live).
"""
import asyncio
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve import audit, durability, faults
from repro.serve.durability import CheckpointStore, iter_records, pack_record
from repro.serve.engine import Engine, Request, RequestStatus
from repro.serve.frontend import AsyncFrontend, PriorityScheduler

KEY = jax.random.PRNGKey(0)

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)


def _engine(scfg: ServeConfig, cfg=CFG):
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    return Engine(cfg, sp, scfg), sp


class TickClock:
    def __init__(self, dt: float = 0.0, t0: float = 0.0):
        self.t = t0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


def _solo_want(sp, prompts, max_new, *, prefill_chunk=32, max_seq_len=32):
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=max_seq_len, batch_size=1,
                                      prefill_chunk=prefill_chunk))
    want = {}
    for i, p in enumerate(prompts):
        ref.reset()
        want[i] = np.asarray(ref.generate(np.asarray(p)[None, :], max_new)[0])
    return want


def _scfg(tmp_path, **kw):
    base = dict(max_seq_len=32, batch_size=3, kv_block_size=8,
                kv_num_blocks=12, paged_attn="gather", audit_interval=1,
                checkpoint_dir=str(tmp_path / "ckpt"))
    base.update(kw)
    return ServeConfig(**base)


# ---------------------------------------------------------------------------
# record framing + array codec
# ---------------------------------------------------------------------------

def test_record_roundtrip_and_corruption():
    payloads = [b"a", b"x" * 100, json.dumps({"k": 1}).encode()]
    blob = b"".join(pack_record(p) for p in payloads)
    got, clean = iter_records(blob)
    assert got == payloads and clean
    # truncation anywhere in the last record drops it, keeps the prefix
    last = len(blob) - len(pack_record(payloads[2]))
    for cut in (len(blob) - 1, last + 9, last + 4, last + 1):
        got, clean = iter_records(blob[:cut])
        assert got == payloads[:2] and not clean
    # a flipped bit in the middle record stops replay there
    bad = bytearray(blob)
    bad[pack_record(payloads[0]).__len__() + 8 + 10] ^= 0x01
    got, clean = iter_records(bytes(bad))
    assert got == payloads[:1] and not clean
    # garbage length field (torn header) never raises
    got, clean = iter_records(blob + b"\xff\xff\xff\xff")
    assert got == payloads and not clean
    assert iter_records(b"") == ([], True)


@pytest.mark.parametrize("dtype", ["float32", "int32", "bfloat16"])
def test_array_codec_lossless(dtype):
    a = np.arange(24, dtype=np.float64).reshape(2, 3, 4) / 7.0
    a = a.astype(durability._np_dtype(dtype))
    d = json.loads(json.dumps(durability.encode_array(a)))
    b = durability.decode_array(d)
    assert b.dtype == a.dtype and b.shape == a.shape
    assert a.tobytes() == b.tobytes()            # bitwise, not approx


# ---------------------------------------------------------------------------
# CheckpointStore: publish, fallback ladder, retention, journal epochs
# ---------------------------------------------------------------------------

SNAP1 = {"fingerprint": ["m", 32], "tick_no": 1, "stats": {}, "key": [0, 1],
         "queue": [], "inflight": [], "payload": "one"}
SNAP2 = {**SNAP1, "tick_no": 2, "payload": "two"}


def test_store_publish_monotonic_and_load_best(tmp_path):
    st = CheckpointStore(tmp_path, keep=3)
    assert st.load_best() == (None, None, 0)
    assert st.write_checkpoint(SNAP1) and st.seq == 1
    assert st.write_checkpoint(SNAP2) and st.seq == 2
    assert st.list_checkpoints() == [1, 2]
    assert st.read_checkpoint(1)["payload"] == "one"
    seq, snap, skipped = st.load_best()
    assert (seq, snap["payload"], skipped) == (2, "two", 0)
    # a new store over the same dir resumes the sequence — no reuse
    st2 = CheckpointStore(tmp_path, keep=3)
    assert st2.seq == 2
    assert st2.write_checkpoint(SNAP1) and st2.list_checkpoints() == [1, 2, 3]


def _ckpt_bytes(path):
    with open(path, "rb") as f:
        return f.read()


def _record_boundaries(data):
    """Offsets of every record boundary in a checkpoint file (after the
    magic+version header), the header offset included."""
    off = len(durability.CKPT_MAGIC) + durability._VER.size
    outs = [off]
    while off < len(data):
        ln, _crc = durability._REC.unpack_from(data, off)
        off += durability._REC.size + ln
        outs.append(off)
    return outs


@pytest.mark.parametrize("cut_kind", ["boundary", "mid_record"])
@pytest.mark.parametrize("boundary", [0, 1, 2])
def test_truncated_newest_falls_back_to_previous(tmp_path, cut_kind,
                                                 boundary):
    """ISSUE satellite: truncate a valid checkpoint at EVERY record
    boundary and mid-record — recovery must degrade to the previous
    checkpoint, never raise."""
    st = CheckpointStore(tmp_path, keep=3)
    st.write_checkpoint(SNAP1)
    st.write_checkpoint(SNAP2)
    path = st._ckpt_path(2)
    data = _ckpt_bytes(path)
    cuts = _record_boundaries(data)
    assert len(cuts) == 4                        # header + 3 records
    cut = cuts[boundary] + (0 if cut_kind == "boundary" else 3)
    with open(path, "wb") as f:
        f.write(data[:cut])
    seq, snap, skipped = CheckpointStore(tmp_path).load_best()
    assert (seq, snap["payload"], skipped) == (1, "one", 1)


def test_flipped_and_unversioned_checkpoints_fall_back(tmp_path):
    st = CheckpointStore(tmp_path, keep=3)
    st.write_checkpoint(SNAP1)
    st.write_checkpoint(SNAP2)
    data = _ckpt_bytes(st._ckpt_path(2))
    flipped = bytearray(data)
    flipped[len(data) // 2] ^= 0x01              # one bit, mid-file
    with open(st._ckpt_path(2), "wb") as f:
        f.write(bytes(flipped))
    assert CheckpointStore(tmp_path).load_best()[0] == 1
    # wrong magic / future version are corruption too, not crashes
    with open(st._ckpt_path(2), "wb") as f:
        f.write(b"NOPE" + data[4:])
    assert CheckpointStore(tmp_path).load_best()[0] == 1
    with open(st._ckpt_path(2), "wb") as f:
        f.write(data[:4] + durability._VER.pack(99) + data[8:])
    assert CheckpointStore(tmp_path).load_best()[0] == 1
    # every checkpoint corrupt -> (None, None, all skipped)
    with open(st._ckpt_path(1), "wb") as f:
        f.write(b"")
    with open(st._ckpt_path(2), "wb") as f:
        f.write(b"\x00")
    assert CheckpointStore(tmp_path).load_best() == (None, None, 2)


def test_retention_prunes_checkpoints_and_stale_journal(tmp_path):
    st = CheckpointStore(tmp_path, keep=2)
    for i in range(5):
        st.append({"ev": "noise", "i": i})       # journal epoch = seq
        assert st.write_checkpoint({**SNAP1, "tick_no": i})
    assert st.list_checkpoints() == [4, 5]       # keep-last-K
    assert all(s >= 4 for s in st.list_journals())
    assert st.stats["pruned_checkpoints"] == 3
    assert st.stats["checkpoints_written"] == 5
    assert st.stats["checkpoint_bytes"] > 0


def test_journal_epochs_and_truncation_at_first_bad_record(tmp_path):
    st = CheckpointStore(tmp_path, keep=5)
    st.append({"ev": "a"})                       # epoch 0 (since boot)
    events, truncated = st.read_journal(0)
    assert [e["ev"] for e in events] == ["a"] and not truncated
    st.write_checkpoint(SNAP1)                   # rotate -> epoch 1 ...
    assert st.list_journals() == []              # ... and prune epoch 0:
    st.append({"ev": "b"})                       # ckpt 1 captured its events
    st.append({"ev": "c"})
    events, truncated = st.read_journal(1)       # tail after checkpoint 1
    assert [e["ev"] for e in events] == ["b", "c"] and not truncated
    # tear the epoch-1 tail: replay keeps the prefix, flags truncation
    st.close()
    path = st._wal_path(1)
    data = _ckpt_bytes(path)
    with open(path, "wb") as f:
        f.write(data[:-3])
    events, truncated = CheckpointStore(tmp_path).read_journal(1)
    assert [e["ev"] for e in events] == ["b"] and truncated
    # ... and a later epoch past the hole is IGNORED (unorderable)
    st2 = CheckpointStore(tmp_path, keep=5)
    st2.write_checkpoint(SNAP2)
    st2.append({"ev": "d"})
    events, truncated = st2.read_journal(1)
    assert [e["ev"] for e in events] == ["b"] and truncated


def test_retire_keeps_journal_until_a_valid_checkpoint_covers_it(tmp_path):
    """Regression: a PUBLISHED checkpoint a disk fault corrupted must not
    license pruning the journal epochs it was supposed to absorb — they
    are the only surviving copy of those requests."""
    plan = faults.FaultPlan.parse("flip@2")      # write 1 = append,
    st = CheckpointStore(tmp_path, keep=3, faults=plan)   # 2 = ckpt temp
    st.append({"ev": "a"})
    assert st.write_checkpoint(SNAP1)            # published ... but flipped
    assert st.read_checkpoint(1) is None
    assert 0 in st.list_journals()               # wal-0 survives: no valid
    events, truncated = st.read_journal(0)       # base checkpoint yet
    assert [e["ev"] for e in events] == ["a"] and not truncated
    assert st.write_checkpoint(SNAP2)            # valid -> now prunable
    assert st.read_checkpoint(2) is not None
    assert all(s >= 2 for s in st.list_journals())


def test_fsync_failure_aborts_checkpoint_publish(tmp_path):
    plan = faults.FaultPlan.parse("fsync@1")
    st = CheckpointStore(tmp_path, keep=3, faults=plan)
    assert st.write_checkpoint(SNAP1) is False   # aborted, not torn
    assert st.list_checkpoints() == [] and st.seq == 0
    assert not [n for n in os.listdir(tmp_path) if n.startswith(".tmp")]
    assert st.stats["checkpoint_failures"] == 1
    assert plan.fired["fsync"] == 1
    assert st.write_checkpoint(SNAP1) is True    # next publish lands


def test_disk_write_seams_tally_and_fire_once():
    plan = faults.FaultPlan.parse("torn@1,flip@2,fsync@2")
    assert plan.take_disk_write() == "torn"
    assert plan.take_disk_write() == "flip"
    assert plan.take_disk_write() is None        # ordinals advance past
    assert not plan.take_fsync() and plan.take_fsync()
    assert not plan.take_fsync()
    assert plan.fired["torn"] == 1 and plan.fired["flip"] == 1
    assert plan.fired["fsync"] == 1


# ---------------------------------------------------------------------------
# snapshot: deep, JSON-serializable, mutation-isolated
# ---------------------------------------------------------------------------

def test_snapshot_is_json_deep_and_mutation_isolated(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_dir="")    # no store: snapshot only
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e)
    rng = np.random.default_rng(3)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=rng.integers(
            1, 64, 9).astype(np.int32), max_new=12,
            on_token=lambda r, t: None))         # non-serializable field
    finished: list = []
    for _ in range(4):
        sched.tick(finished)
    snap = sched.snapshot()
    frozen = json.dumps(snap, sort_keys=True)    # would raise on any
    # non-JSON leaf (ndarray, callable, jax array)
    d = snap["inflight"][0]
    assert d["streaming"] is True and "on_token" not in d
    audit.audit_snapshot(snap)
    # mutation isolation: keep ticking, the captured dict must not move
    while not sched.idle:
        sched.tick(finished)
    assert json.dumps(snap, sort_keys=True) == frozen


def test_audit_snapshot_names_the_broken_invariant():
    good = {"fingerprint": ["m"], "tick_no": 0, "stats": {}, "key": [0, 1],
            "queue": [{"rid": 1, "prompt": [1, 2], "max_new": 4,
                       "generated": []}],
            "inflight": [{"rid": 2, "prompt": [3], "max_new": 4,
                          "generated": [5]}],
            "registered": [["ab", 0]], "kv": {"k": durability.encode_array(
                np.zeros(2, np.float32))}}
    audit.audit_snapshot(good)
    cases = [
        ("S1", {k: v for k, v in good.items() if k != "queue"}),
        ("S1", {**good, "tick_no": "zero"}),
        ("S2", {**good, "queue": [{"prompt": [1], "max_new": 1,
                                   "generated": []}]}),
        ("S2", {**good, "queue": [{"rid": 1, "prompt": [], "max_new": 1,
                                   "generated": []}]}),
        ("S2", {**good, "queue": [{"rid": 1, "prompt": [1], "max_new": 1,
                                   "generated": [1, 2]}]}),
        ("S3", {**good, "queue": good["queue"] + [good["inflight"][0]]}),
        ("S4", {**good, "registered": [["ab", 0], ["cd", 0]]}),
        ("S4", {**good, "kv": {}}),
        ("S4", {**good, "kv": {"k": {"dtype": "float32"}}}),
    ]
    for inv, snap in cases:
        with pytest.raises(audit.AuditError) as err:
            audit.audit_snapshot(snap)
        assert err.value.invariant == inv


# ---------------------------------------------------------------------------
# periodic checkpoint policy + journaling on the live scheduler
# ---------------------------------------------------------------------------

def test_tick_interval_checkpoints_and_journal(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_interval=2)
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e)
    rng = np.random.default_rng(5)
    for i in range(2):
        sched.submit(Request(rid=i, prompt=rng.integers(
            1, 64, 8).astype(np.int32), max_new=6))
    done = sched.run()
    assert len(done) == 2
    st = sched._ckpt_store
    assert st.list_checkpoints()                 # every 2nd tick published
    assert sched.stats["checkpoints"] == st.stats["checkpoints_written"]
    # the journal saw 2 submits + 2 terminals (the submits landed in
    # epoch 0, since pruned — checkpoints captured those requests)
    assert sched.stats["journal_events"] == 4
    events, truncated = st.read_journal(0)
    kinds = [ev["ev"] for ev in events]
    assert kinds.count("terminal") == 2 and not truncated
    # terminal events carry the exact final tokens
    by_rid = {r.rid: r for r in done}
    for ev in events:
        if ev["ev"] == "terminal":
            assert ev["req"]["generated"] == \
                list(by_rid[ev["req"]["rid"]].generated)


def test_wall_clock_interval_checkpoints(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_interval=0, checkpoint_interval_s=5.0)
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e, clock=TickClock(1.0))
    sched.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                         max_new=12))
    sched.run()
    st = sched._ckpt_store
    assert st.list_checkpoints()                 # the 5s period elapsed
    assert len(st.list_checkpoints()) <= 3       # keep-last-K retention
    assert sched.stats["checkpoints"] == st.stats["checkpoints_written"]


def test_env_overrides_outrank_scfg(tmp_path, monkeypatch):
    env_dir = tmp_path / "env-ckpt"
    monkeypatch.setenv("REPRO_CHECKPOINT_DIR", str(env_dir))
    monkeypatch.setenv("REPRO_CHECKPOINT_INTERVAL", "1")
    scfg = _scfg(tmp_path, checkpoint_dir=str(tmp_path / "scfg-ckpt"),
                 checkpoint_interval=0)
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                         max_new=4))
    sched.run()
    assert sched._ckpt_store.dir == str(env_dir)
    assert sched._ckpt_store.list_checkpoints()  # interval 1 from env
    assert not (tmp_path / "scfg-ckpt").exists()


def test_checkpoint_without_store_raises(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_dir="")
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e)
    with pytest.raises(RuntimeError, match="checkpoint directory"):
        sched.checkpoint()
    with pytest.raises(ValueError, match="checkpoint directory"):
        durability.recover_scheduler(_engine(scfg)[0])


# ---------------------------------------------------------------------------
# recovery: crash -> recover -> drain, bitwise
# ---------------------------------------------------------------------------

def test_crash_recover_drain_is_bitwise_and_leak_free(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_interval=0)   # manual checkpoint:
    e, sp = _engine(scfg)                        # the terminal must land in
    rng = np.random.default_rng(14)              # the journal tail AFTER it
    prompts = [rng.integers(1, 64, 9).astype(np.int32) for _ in range(4)]
    max_new = [4, 14, 14, 14]                    # rid 0 completes pre-crash
    want = _solo_want(sp, prompts, max(max_new))
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=max_new[i]))
    finished: list = []
    for _ in range(2):
        sched.tick(finished)
    assert not finished
    assert sched.checkpoint()                    # everyone mid-flight
    while not any(r.rid == 0 for r in finished):
        sched.tick(finished)
    # hard crash: the process state is abandoned; only the disk survives
    pre_crash = {r.rid: list(r.generated) for r in finished}
    e2, _ = _engine(scfg)
    sched2, report = durability.recover_scheduler(e2, clock=None)
    assert report["checkpoint_seq"] is not None
    assert report["checkpoints_skipped"] == 0
    # rid 0 finished before the crash: settled verbatim off the journal,
    # not recomputed, not requeued
    done_rids = [r.rid for r in report["completed"]]
    assert 0 in done_rids
    for r in report["completed"]:
        assert list(r.generated) == pre_crash[r.rid]
        assert r.status is RequestStatus.OK and r.done
    assert report["requeued"] == 4 - len(done_rids)
    assert report["resumed_inflight"] >= 1       # partial output survived
    got = {r.rid: list(r.generated) for r in report["completed"]}
    for r in sched2.run():
        got[r.rid] = list(r.generated)
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(got[i]), want[i][:max_new[i]])
    assert e2.pool.free_count == e2.pool.num_blocks   # zero leaks
    assert e2.pool.live_refs == 0
    audit.audit_scheduler(sched2)


def test_recover_with_corrupt_newest_checkpoint_falls_back(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_interval=2)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(21)
    prompts = [rng.integers(1, 64, 9).astype(np.int32) for _ in range(3)]
    want = _solo_want(sp, prompts, 12)
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=12))
    finished: list = []
    for _ in range(7):
        sched.tick(finished)
    st = sched._ckpt_store
    assert len(st.list_checkpoints()) >= 2
    newest = st.list_checkpoints()[-1]
    data = _ckpt_bytes(st._ckpt_path(newest))
    with open(st._ckpt_path(newest), "wb") as f:
        f.write(data[:len(data) // 2])           # torn newest
    e2, _ = _engine(scfg)
    sched2, report = durability.recover_scheduler(e2)
    assert report["checkpoints_skipped"] == 1
    assert report["checkpoint_seq"] == newest - 1
    got = {r.rid: list(r.generated) for r in sched2.run()}
    for r in report["completed"]:
        got[r.rid] = list(r.generated)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(got[i]), want[i])


def test_recover_refuses_wrong_engine_fingerprint(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_interval=1)
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                         max_new=8))
    finished: list = []
    sched.tick(finished)
    assert sched._ckpt_store.list_checkpoints()
    # same directory, different geometry: VALID checkpoint, wrong engine
    other = dataclasses.replace(scfg, kv_num_blocks=10)
    e2, _ = _engine(other)
    with pytest.raises(ValueError, match="fingerprint"):
        durability.recover_scheduler(e2)


def test_recover_from_journal_only(tmp_path):
    """No checkpoint ever published (interval 0): recovery rebuilds the
    whole queue from wal-0 alone."""
    scfg = _scfg(tmp_path, checkpoint_interval=0)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, 64, 8).astype(np.int32) for _ in range(2)]
    want = _solo_want(sp, prompts, 6)
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=6))
    # crash before the first tick: only submit events exist
    e2, _ = _engine(scfg)
    sched2, report = durability.recover_scheduler(e2)
    assert report["checkpoint_seq"] is None and report["requeued"] == 2
    got = {r.rid: list(r.generated) for r in sched2.run()}
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(got[i]), want[i])


def test_recovery_draws_a_clean_checkpoint_line(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_interval=0)
    e, sp = _engine(scfg)
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                         max_new=6))
    e2, _ = _engine(scfg)
    sched2, _report = durability.recover_scheduler(e2)
    st = sched2._ckpt_store
    assert st.list_checkpoints()                 # recovery checkpointed
    # the new epoch starts clean: replay from it sees no pre-crash events
    events, truncated = st.read_journal(st.seq)
    assert events == [] and not truncated


def test_async_frontend_recover(tmp_path):
    scfg = _scfg(tmp_path, checkpoint_interval=2)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(30)
    prompts = [rng.integers(1, 64, 8).astype(np.int32) for _ in range(3)]
    want = _solo_want(sp, prompts, 10)
    fe = AsyncFrontend(e)
    reqs = [fe.submit(p, 10) for p in prompts]
    finished: list = []
    for _ in range(5):
        fe.scheduler.tick(finished)
    e2, _ = _engine(scfg)
    fe2 = AsyncFrontend.recover(e2)
    assert fe2.recovery_report["requeued"] + \
        len(fe2.recovery_report["completed"]) == 3
    # fresh rids continue past every recovered one
    fresh = fe2.submit(prompts[0], 2)
    assert fresh.rid > max(r.rid for r in reqs)
    drained = asyncio.run(asyncio.wait_for(fe2.drain(), 60))
    got = {r.rid: list(r.generated) for r in fe2._finished + drained}
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(got[i]), want[i])
    np.testing.assert_array_equal(np.asarray(got[fresh.rid]), want[0][:2])


# ---------------------------------------------------------------------------
# disk-fault chaos: torn/flip/fsync live while serving + recovering
# ---------------------------------------------------------------------------

def test_serving_survives_disk_faults_and_recovers(tmp_path):
    """torn + flip land in published checkpoints (the fallback ladder's
    job), fsync aborts one publish — the plane never raises, and
    recovery after a mid-run kill still reaches bitwise parity."""
    plan = faults.FaultPlan.parse("torn@3,flip@5,fsync@2")
    scfg = _scfg(tmp_path, checkpoint_interval=1)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(17)
    prompts = [rng.integers(1, 64, 9).astype(np.int32) for _ in range(3)]
    want = _solo_want(sp, prompts, 12)
    sched = PriorityScheduler(e, fault_plan=plan)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=12))
    finished: list = []
    for _ in range(8):
        sched.tick(finished)
    fired = sched.fault_plan.fired
    assert fired["torn"] + fired["flip"] + fired["fsync"] >= 2
    sstats = sched._ckpt_store.stats
    assert (sstats["torn_writes"] + sstats["bit_flips"]
            + sstats["fsync_failures"]) >= 2     # the seams hit the store
    # kill; recover with NO faults (the disk is what it is now)
    e2, _ = _engine(scfg)
    sched2, report = durability.recover_scheduler(e2)
    got = {r.rid: list(r.generated) for r in report["completed"]}
    for r in sched2.run():
        got[r.rid] = list(r.generated)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(got[i]), want[i])
    assert e2.pool.live_refs == 0
    audit.audit_scheduler(sched2)
