"""reprolint: every checker must FIRE on a seeded violation and stay
QUIET (modulo the committed baseline) on the real tree — a static gate
that cannot catch its target class of bug is worse than none."""
import json
import os
import textwrap

import pytest

from repro.analysis import (boundaries, dtypeflow, envdocs, metricsdocs,
                            run_checks, tiles)
from repro.analysis.findings import (Finding, load_baseline, save_baseline,
                                     split_findings)
from repro.config import ModelConfig
from repro.kernels.paged_attention import PAGED_ATTN_TILES
from repro.roofline import hw

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _codes(findings):
    return {f.code for f in findings}


# ---------------------------------------------------------------------------
# tiles (RL1xx) — seeded violations via injected tables
# ---------------------------------------------------------------------------

def test_tiles_flags_misaligned_tile():
    """A 300-wide output tile violates both the 128-lane quantum and the
    4-codes-per-word divisibility."""
    bad = (("decode", None, 8, 8, 300),)
    fs = tiles.check_rsr_shape("t", nb=64, n=2048, k=5, table=bad, tuned={})
    assert _codes(fs) == {"RL102"}
    assert "lane" in fs[0].message


def test_tiles_flags_vmem_overflow():
    """(256, 256, 4096) tiles put the 3^5-wide scratch alone far past the
    per-launch budget."""
    huge = (("prefill", None, 256, 256, 4096),)
    fs = tiles.check_rsr_shape("t", nb=512, n=8192, k=5, table=huge,
                               tuned={}, rows=(256,))
    assert "RL101" in _codes(fs)


def test_tiles_flags_uncovered_regime():
    decode_only = (("decode", 8, 8, 8, 512),)
    fs = tiles.check_rsr_shape("t", nb=64, n=2048, k=5, table=decode_only,
                               tuned={}, rows=(256,))
    assert _codes(fs) == {"RL103"}


def test_tiles_tuned_overlay_outranks_static_row():
    """A bad TUNED entry must be caught even when the static row is fine."""
    good = (("decode", None, 8, 8, 512),)
    tuned = {("decode", 64, 2048): (8, 8, 300)}
    fs = tiles.check_rsr_shape("t", nb=64, n=2048, k=5, table=good,
                               tuned=tuned, rows=(8,))
    assert _codes(fs) == {"RL102"}


def test_tiles_clamped_tiles_stay_quiet():
    """The real static table + clamping is clean for an awkward shape."""
    from repro.kernels.dispatch import AUTOTUNE_TABLE
    fs = tiles.check_rsr_shape("t", nb=57, n=130, k=5,
                               table=AUTOTUNE_TABLE, tuned={})
    assert fs == []


def test_tiles_flags_sublane_head_dim():
    """hd = 512/8 = 64 < the 128-lane quantum: the paged pools pad 2x."""
    cfg = ModelConfig(name="t", family="dense", d_model=512, num_heads=8)
    fs = tiles.check_attn_geometry(cfg, table=PAGED_ATTN_TILES, tuned={})
    assert _codes(fs) == {"RL102"}
    assert "head_dim=64" in fs[0].symbol


def test_tiles_attn_vmem_overflow_fires():
    cfg = ModelConfig(name="t", family="dense", d_model=16384,
                      num_heads=128, head_dim=128)
    fs = tiles.check_attn_geometry(cfg, table=PAGED_ATTN_TILES, tuned={},
                                   budget=2 ** 20)
    assert "RL101" in _codes(fs)


def test_tiles_reports_malformed_overlay(tmp_path):
    (tmp_path / "autotune_cache.json").write_text(json.dumps({
        "schema": "autotune_cache_v1", "host_backend": None,
        "entries": [{"regime": "decode", "nb_bucket": 64, "n_bucket": 2048,
                     "tiles": [8, -8, 512]}], "attn_entries": []}))
    fs = tiles.check(str(tmp_path), archs=[])
    assert _codes(fs) == {"RL104"}


# ---------------------------------------------------------------------------
# boundaries (RL2xx) — seeded violations via synthetic sources
# ---------------------------------------------------------------------------

def test_boundary_flags_traced_value_into_host_state():
    src = textwrap.dedent("""
        class Pool:
            def tick(self, x):
                self._free = jnp.cumsum(x)
    """)
    fs = boundaries.check_serve_source("src/repro/serve/x.py", src)
    assert _codes(fs) == {"RL201"}
    assert fs[0].symbol == "_free"


def test_boundary_wrappers_shield_assignment():
    src = textwrap.dedent("""
        class Pool:
            def tick(self, x, y):
                self._pos = int(jnp.argmax(x))
                self._tables = np.asarray(jax.device_get(y))
    """)
    assert boundaries.check_serve_source("src/repro/serve/x.py", src) == []


def test_boundary_flags_jnp_math_on_host_state():
    src = textwrap.dedent("""
        class Pool:
            def tick(self):
                return jnp.sum(self._pos)
    """)
    fs = boundaries.check_serve_source("src/repro/serve/x.py", src)
    assert _codes(fs) == {"RL202"}


def test_boundary_jnp_conversion_of_host_state_is_fine():
    src = textwrap.dedent("""
        class Eng:
            def step(self, slot):
                return jnp.asarray(self._tables[slot])
    """)
    assert boundaries.check_serve_source("src/repro/serve/x.py", src) == []


def test_boundary_flags_host_op_in_jitted_fn():
    files = {"src/repro/kernels/k.py": textwrap.dedent("""
        @jax.jit
        def f(x):
            np.save("/tmp/x", x)
            return x
    """)}
    fs = boundaries.check_traced_tree(files)
    assert _codes(fs) == {"RL203"}


def test_boundary_flags_env_read_reached_through_call_graph():
    """jit root -> helper -> os.environ: the read is flagged on the helper."""
    files = {"src/repro/kernels/k.py": textwrap.dedent("""
        def helper(x):
            return os.environ.get("REPRO_X", x)

        @jax.jit
        def f(x):
            return helper(x)
    """)}
    fs = boundaries.check_traced_tree(files)
    assert _codes(fs) == {"RL203"}
    assert fs[0].symbol.startswith("helper:")


def test_boundary_flags_pallas_body_via_partial():
    files = {"src/repro/kernels/k.py": textwrap.dedent("""
        def _body(x_ref, o_ref, *, n):
            print(x_ref)
            o_ref[...] = x_ref[...]

        def launch(x, n):
            kernel = functools.partial(_body, n=n)
            return pl.pallas_call(kernel, out_shape=x)(x)
    """)}
    fs = boundaries.check_traced_tree(files)
    assert _codes(fs) == {"RL203"}
    assert fs[0].symbol.startswith("_body:")


def test_boundary_untraced_helper_stays_quiet():
    files = {"src/repro/kernels/k.py": textwrap.dedent("""
        def save_cache(path, payload):
            with open(path, "w") as f:
                f.write(payload)
    """)}
    assert boundaries.check_traced_tree(files) == []


# ---------------------------------------------------------------------------
# dtypeflow (RL3xx)
# ---------------------------------------------------------------------------

def test_dtypeflow_flags_code_word_float_cast():
    src = textwrap.dedent("""
        def f(p):
            codes = p["codes"]
            return codes.astype(jnp.float32)
    """)
    fs = dtypeflow.check_source("src/repro/core/x.py", src)
    assert _codes(fs) == {"RL301"}


def test_dtypeflow_taint_through_producer_and_assignment():
    src = textwrap.dedent("""
        def f(stream):
            w = unpack_code_words(stream)
            v = w
            return jnp.asarray(v, dtype=jnp.float16)
    """)
    fs = dtypeflow.check_source("src/repro/core/x.py", src)
    assert _codes(fs) == {"RL301"}


def test_dtypeflow_comparison_launders_taint():
    """The kernels' one-hot build casts the BOOLEAN of codes == iota."""
    src = textwrap.dedent("""
        def f(codes, iota):
            oh = (codes[:, None] == iota).astype(jnp.float32)
            return oh
    """)
    assert dtypeflow.check_source("src/repro/kernels/x.py", src) == []


def test_dtypeflow_int_casts_are_fine():
    src = textwrap.dedent("""
        def f(codes_ref):
            return codes_ref[...].astype(jnp.int32)
    """)
    assert dtypeflow.check_source("src/repro/kernels/x.py", src) == []


def test_dtypeflow_flags_narrowed_scale():
    src = textwrap.dedent("""
        def f(scale):
            return scale.astype(jnp.bfloat16)
    """)
    fs = dtypeflow.check_source("src/repro/models/x.py", src)
    assert _codes(fs) == {"RL302"}


def test_dtypeflow_f32_scale_is_fine():
    src = textwrap.dedent("""
        def f(scale):
            return scale.astype(jnp.float32)
    """)
    assert dtypeflow.check_source("src/repro/models/x.py", src) == []


# ---------------------------------------------------------------------------
# envdocs (RL4xx) — seeded drift in a temp tree
# ---------------------------------------------------------------------------

def _env_tree(tmp_path, doc_vars, reader_src):
    serve = tmp_path / "src" / "repro" / "serve"
    serve.mkdir(parents=True)
    rows = "\n".join(f"``{v}``  doc row" for v in doc_vars)
    (serve / "__init__.py").write_text(f'"""env table\n\n{rows}\n"""\n')
    (tmp_path / "src" / "m.py").write_text(reader_src)
    return str(tmp_path)


def test_envdocs_flags_undocumented_read(tmp_path):
    root = _env_tree(tmp_path, [], textwrap.dedent("""
        import os
        _ENV_VAR = "REPRO_INDIRECT"
        A = os.environ.get("REPRO_DIRECT")
        B = os.getenv(_ENV_VAR)
        C = os.environ["REPRO_SUBSCRIPT"]
    """))
    fs = envdocs.check(root)
    assert _codes(fs) == {"RL401"}
    assert {f.symbol for f in fs} == {"REPRO_DIRECT", "REPRO_INDIRECT",
                                      "REPRO_SUBSCRIPT"}


def test_envdocs_flags_stale_doc_row(tmp_path):
    root = _env_tree(tmp_path, ["REPRO_GONE"], "import os\n")
    fs = envdocs.check(root)
    assert _codes(fs) == {"RL402"}
    assert fs[0].symbol == "REPRO_GONE"


def test_envdocs_documented_read_is_quiet(tmp_path):
    root = _env_tree(tmp_path, ["REPRO_OK"],
                     'import os\nA = os.environ.get("REPRO_OK")\n')
    assert envdocs.check(root) == []


# ---------------------------------------------------------------------------
# metricsdocs (RL5xx) — seeded catalog drift in a temp tree
# ---------------------------------------------------------------------------

def _metric_tree(tmp_path, doc_names, emitter_src):
    serve = tmp_path / "src" / "repro" / "serve"
    serve.mkdir(parents=True)
    rows = "\n".join(f"``{n}``  catalog row" for n in doc_names)
    (serve / "__init__.py").write_text(f'"""metric catalog\n\n{rows}\n"""\n')
    (tmp_path / "src" / "m.py").write_text(emitter_src)
    return str(tmp_path)


def test_metricsdocs_flags_undocumented_emit(tmp_path):
    """Literal, name-constant-indirect, and attribute-call emissions are
    all resolved; non-metric strings and non-constructor calls are not."""
    root = _metric_tree(tmp_path, [], textwrap.dedent("""
        from repro.serve import telemetry
        _NAME = "rsr_indirect_total"
        a = telemetry.Counter("serve_direct_total", "h")
        b = telemetry.Histogram(_NAME, "h", ())
        def wire(tel):
            return tel.gauge("serve_attr_gauge", "h")
        c = print("serve_not_a_metric")
        d = telemetry.Counter("unprefixed_name", "h")
    """))
    fs = metricsdocs.check(root)
    assert _codes(fs) == {"RL501"}
    assert {f.symbol for f in fs} == {"serve_direct_total",
                                      "rsr_indirect_total",
                                      "serve_attr_gauge"}
    assert all(f.path == "src/m.py" and f.line for f in fs)


def test_metricsdocs_flags_stale_catalog_row(tmp_path):
    root = _metric_tree(tmp_path, ["serve_gone_total"], "x = 1\n")
    fs = metricsdocs.check(root)
    assert _codes(fs) == {"RL502"}
    assert fs[0].symbol == "serve_gone_total"
    assert fs[0].path == "src/repro/serve/__init__.py"


def test_metricsdocs_documented_emit_is_quiet(tmp_path):
    root = _metric_tree(
        tmp_path, ["serve_ok_total"],
        'from repro.serve import telemetry\n'
        'c = telemetry.stats_counters("serve_ok_total", ("a",))\n')
    assert metricsdocs.check(root) == []


# ---------------------------------------------------------------------------
# baseline machinery
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_and_todo_rejection(tmp_path):
    path = str(tmp_path / "baseline.json")
    f = Finding("RL999", "a.py", "sym", "msg")
    save_baseline(path, [f])
    with pytest.raises(ValueError, match="TODO"):
        load_baseline(path)       # fresh entries need a human justification
    save_baseline(path, [f], previous={f.key: "known and accepted"})
    baseline = load_baseline(path)
    assert baseline == {f.key: "known and accepted"}
    new, suppressed, stale = split_findings([f], baseline)
    assert (new, suppressed) == ([], [f])
    _, _, stale = split_findings([], baseline)
    assert stale == [f.key]


def test_baseline_bad_schema_rejected(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"schema": "nope", "suppressions": []}))
    with pytest.raises(ValueError, match="schema"):
        load_baseline(str(p))


# ---------------------------------------------------------------------------
# the committed tree is clean (modulo the committed baseline)
# ---------------------------------------------------------------------------

def test_fast_checkers_clean_on_real_tree():
    """AST checkers over the real tree: everything not in the committed
    baseline must be quiet."""
    findings = run_checks(ROOT, ["boundaries", "dtypeflow", "envdocs",
                                 "metricsdocs"])
    baseline = load_baseline(os.path.join(ROOT, "reprolint_baseline.json"))
    new, _, _ = split_findings(findings, baseline)
    assert new == [], "\n".join(f.render() for f in new)


@pytest.mark.slow
def test_full_lint_clean_on_real_tree():
    """The full gate (incl. the eval_shape sweep over the config zoo)
    reports nothing outside the committed baseline, and the baseline
    carries no stale entries."""
    findings = run_checks(ROOT)
    baseline = load_baseline(os.path.join(ROOT, "reprolint_baseline.json"))
    new, suppressed, stale = split_findings(findings, baseline)
    assert new == [], "\n".join(f.render() for f in new)
    assert stale == []
    assert len(suppressed) == len(baseline)
