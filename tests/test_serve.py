"""Serving engine: generation determinism, RSR==dense generation, scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve.engine import BatchScheduler, Engine, Request

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)
KEY = jax.random.PRNGKey(0)


def _engines():
    params = tfm.init_params(CFG, KEY)
    sp_rsr = tfm.serve_params(params, CFG)
    sp_dense = tfm.serve_params(params,
                                dataclasses.replace(CFG, rsr_serve=False))
    scfg = ServeConfig(max_seq_len=64, batch_size=2)
    return Engine(CFG, sp_rsr, scfg), Engine(CFG, sp_dense, scfg)


def test_rsr_engine_generates_same_tokens_as_dense():
    """Paper §5.3 check: 'verified the equality of responses with and
    without applying RSR' — greedy decodes must match token-for-token."""
    e_rsr, e_dense = _engines()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 CFG.vocab_size)
    t1 = e_rsr.generate(prompts, max_new=12)
    t2 = e_dense.generate(prompts, max_new=12)
    np.testing.assert_array_equal(t1, t2)


def test_generation_deterministic():
    e, _ = _engines()
    prompts = jnp.ones((2, 4), jnp.int32)
    a = e.generate(prompts, max_new=6)
    e.reset()
    b = e.generate(prompts, max_new=6)
    np.testing.assert_array_equal(a, b)


def test_batch_scheduler_completes_requests():
    e, _ = _engines()
    sched = BatchScheduler(e)
    for i in range(5):
        sched.submit(Request(rid=i, prompt=np.ones(4, np.int32) * (i + 1),
                             max_new=3))
    done = sched.run()
    assert len(done) == 5
    assert all(r.done and len(r.generated) == 3 for r in done)
