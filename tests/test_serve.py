"""Serving engine: generation determinism, RSR==dense generation, chunked
prefill parity vs the decode-step-scan reference, continuous batching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve.engine import BatchScheduler, Engine, Request

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)
KEY = jax.random.PRNGKey(0)


def _engines():
    params = tfm.init_params(CFG, KEY)
    sp_rsr = tfm.serve_params(params, CFG)
    sp_dense = tfm.serve_params(params,
                                dataclasses.replace(CFG, rsr_serve=False))
    scfg = ServeConfig(max_seq_len=64, batch_size=2)
    return Engine(CFG, sp_rsr, scfg), Engine(CFG, sp_dense, scfg)


def test_rsr_engine_generates_same_tokens_as_dense():
    """Paper §5.3 check: 'verified the equality of responses with and
    without applying RSR' — greedy decodes must match token-for-token."""
    e_rsr, e_dense = _engines()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 CFG.vocab_size)
    t1 = e_rsr.generate(prompts, max_new=12)
    t2 = e_dense.generate(prompts, max_new=12)
    np.testing.assert_array_equal(t1, t2)


def test_generation_deterministic():
    e, _ = _engines()
    prompts = jnp.ones((2, 4), jnp.int32)
    a = e.generate(prompts, max_new=6)
    e.reset()
    b = e.generate(prompts, max_new=6)
    np.testing.assert_array_equal(a, b)


def test_batch_scheduler_completes_requests():
    e, _ = _engines()
    sched = BatchScheduler(e)
    for i in range(5):
        sched.submit(Request(rid=i, prompt=np.ones(4, np.int32) * (i + 1),
                             max_new=3))
    done = sched.run()
    assert len(done) == 5
    assert all(r.done and len(r.generated) == 3 for r in done)


@pytest.mark.parametrize("backend", ["pallas_interpret", "scatter"])
def test_chunked_prefill_parity_vs_scan(backend):
    """prefill_chunk ∈ {1, 7, S}: bitwise-identical KV cache and
    last-position logits vs the decode-step-scan reference, per backend."""
    cfg = dataclasses.replace(CFG, rsr_backend=backend)
    params = tfm.init_params(cfg, KEY)
    e = Engine(cfg, tfm.serve_params(params, cfg),
               ServeConfig(max_seq_len=32, batch_size=2))
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                                 cfg.vocab_size)
    ref_logits = e.prefill_scan(prompts)
    ref_cache = e.cache
    for chunk in (1, 7, 12):          # 7 exercises a ragged tail chunk
        e.reset()
        logits = e.prefill(prompts, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(logits),
                                      np.asarray(ref_logits))
        for a, b in zip(jax.tree.leaves(ref_cache), jax.tree.leaves(e.cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefill_into_isolates_slot():
    """Per-slot admission prefill must not disturb the other slots' rows."""
    e, _ = _engines()
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 6), 0,
                                 CFG.vocab_size)
    e.prefill(prompts)
    before = jax.tree.leaves(tfm.slot_cache(e.cache, 0))
    e.prefill_into(1, np.arange(1, 10, dtype=np.int32), chunk=4)
    after = jax.tree.leaves(tfm.slot_cache(e.cache, 0))
    for a, b in zip(before, after):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(tfm.slot_cache(e.cache, 1)["pos"][0]) == 9


def test_scheduler_mixed_prompt_lengths_match_per_request():
    """Left-padding regression: short prompts in a mixed wave must decode
    exactly what they decode alone (no attending to pad tokens)."""
    params = tfm.init_params(CFG, KEY)
    sp = tfm.serve_params(params, CFG)
    e = Engine(CFG, sp, ServeConfig(max_seq_len=32, batch_size=2,
                                    prefill_chunk=4))
    sched = BatchScheduler(e)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, CFG.vocab_size, n).astype(np.int32)
               for n in (3, 9, 5, 7)]
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    done = sched.run()
    assert len(done) == 4
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=32, batch_size=1,
                                      prefill_chunk=4))
    for r in sorted(done, key=lambda r: r.rid):
        ref.reset()
        want = ref.generate(jnp.asarray(r.prompt)[None, :], r.max_new)[0]
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(want))


def test_decode_throughput_overflow_guard():
    """Slot positions past max_seq_len must raise, not silently wrap."""
    e, _ = _engines()                  # max_seq_len = 64
    e.prefill(jnp.ones((2, 8), jnp.int32))
    with pytest.raises(ValueError):
        e.decode_throughput(steps=80)
    e.decode_throughput(steps=2, warmup=1)     # within budget: fine


# ---------------------------------------------------------------------------
# Paged KV cache (PR 3): bitwise parity vs the dense layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas_interpret", "scatter"])
def test_paged_generate_bitwise_matches_dense(backend):
    """The paged-cache parity bar (same discipline as the PR-2 chunk-vs-
    scan tests): block-paged decode AND prefill in GATHER mode must be
    bitwise-equal to the dense layout on the serve test config, per RSR
    backend.  (Gather is the parity reference; the in-place kernel's bar is
    token equality + tight allclose — tests/test_paged_attn.py.)"""
    cfg = dataclasses.replace(CFG, rsr_backend=backend)
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    scfg = ServeConfig(max_seq_len=64, batch_size=2)
    e_dense = Engine(cfg, sp, scfg)
    e_paged = Engine(cfg, sp, dataclasses.replace(scfg, kv_block_size=8,
                                                  paged_attn="gather"))
    assert e_paged.paged and not e_dense.paged
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 9), 0,
                                 cfg.vocab_size)
    lg_d = e_dense.prefill(prompts, start=0)
    lg_p = e_paged.prefill(prompts, start=0)
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))
    e_dense.reset(), e_paged.reset()
    t_d = e_dense.generate(prompts, max_new=12)
    t_p = e_paged.generate(prompts, max_new=12)
    np.testing.assert_array_equal(t_d, t_p)


def test_paged_prefill_chunk_parity():
    """Paged chunked prefill (gather mode) across chunk sizes (incl. a
    ragged tail) must produce dense-identical last-position logits."""
    params = tfm.init_params(CFG, KEY)
    sp = tfm.serve_params(params, CFG)
    scfg = ServeConfig(max_seq_len=32, batch_size=2)
    e_dense = Engine(CFG, sp, scfg)
    e_paged = Engine(CFG, sp, dataclasses.replace(scfg, kv_block_size=4,
                                                  paged_attn="gather"))
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 12), 0,
                                 CFG.vocab_size)
    ref = np.asarray(e_dense.prefill(prompts, start=0))
    for chunk in (1, 7, 12):
        e_paged.reset()
        got = np.asarray(e_paged.prefill(prompts, chunk=chunk, start=0))
        np.testing.assert_array_equal(got, ref)


def test_paged_prefill_into_isolates_slot():
    """Per-slot paged admission must not disturb another slot's blocks."""
    params = tfm.init_params(CFG, KEY)
    sp = tfm.serve_params(params, CFG)
    e = Engine(CFG, sp, ServeConfig(max_seq_len=64, batch_size=2,
                                    kv_block_size=8))
    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 6), 0,
                                 CFG.vocab_size)
    e.prefill(prompts, start=0)
    table0 = e._tables[0].copy()
    before = [np.asarray(l) for l in
              jax.tree.leaves(tfm.slot_cache(e.cache, 0, paged=True))]
    e.prefill_into(1, np.arange(1, 10, dtype=np.int32), chunk=4)
    np.testing.assert_array_equal(e._tables[0], table0)
    after = [np.asarray(l) for l in
             jax.tree.leaves(tfm.slot_cache(e.cache, 0, paged=True))]
    # slot 0's view: table/pos rows and its blocks' contents are untouched
    # (pool arrays are shared, so compare the gathered per-slot view)
    for a, b in zip(before, after):
        if a.shape == b.shape and a.ndim >= 1 and a.shape[0] != 1:
            # pool leaf: compare only slot-0-owned blocks
            for bid in [x for x in table0 if x != e.layout.trash_block]:
                np.testing.assert_array_equal(a[..., bid, :, :, :]
                                              if a.ndim > 4 else a[bid],
                                              b[..., bid, :, :, :]
                                              if b.ndim > 4 else b[bid])
        else:
            np.testing.assert_array_equal(a, b)
    assert int(tfm.slot_cache(e.cache, 1, paged=True)["pos"][0]) == 9


# ---------------------------------------------------------------------------
# Scheduler robustness (PR 3 satellite bugfixes)
# ---------------------------------------------------------------------------

def test_scheduler_oversized_request_does_not_abandon_queue():
    """Regression: an oversized request used to raise mid-run(), abandoning
    all queued and in-flight requests.  It must be marked failed at
    submit() and the rest of the queue must drain normally."""
    e, _ = _engines()                  # max_seq_len = 64
    sched = BatchScheduler(e)
    good = [Request(rid=i, prompt=np.ones(4, np.int32) * (i + 1), max_new=3)
            for i in range(3)]
    oversized = Request(rid=99, prompt=np.ones(60, np.int32), max_new=10)
    bad_shape = Request(rid=98, prompt=np.zeros((0,), np.int32), max_new=2)
    sched.submit(good[0])
    sched.submit(oversized)            # rejected, queue keeps draining
    sched.submit(good[1])
    sched.submit(bad_shape)
    sched.submit(good[2])
    done = sched.run()
    assert len(done) == 5
    by_rid = {r.rid: r for r in done}
    assert by_rid[99].error and "max_seq_len" in by_rid[99].error
    assert by_rid[98].error
    for r in good:
        assert by_rid[r.rid].done and not by_rid[r.rid].error
        assert len(by_rid[r.rid].generated) == 3


def test_generate_max_new_zero_and_one():
    """Regression: generate(prompts, max_new=0) returned shape (B, 1)
    because the prefill-sampled token was emitted unconditionally."""
    e, _ = _engines()
    prompts = jnp.ones((2, 4), jnp.int32)
    out0 = e.generate(prompts, max_new=0)
    assert out0.shape == (2, 0)
    e.reset()
    out1 = e.generate(prompts, max_new=1)
    assert out1.shape == (2, 1)
    e.reset()
    out3 = e.generate(prompts, max_new=3)
    assert out3.shape == (2, 3)
    np.testing.assert_array_equal(out1, out3[:, :1])   # greedy: same head
