"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property tests only use ``@given`` with ``st.sampled_from`` strategies
plus ``@settings(max_examples=..., deadline=None)``.  When hypothesis is
available the real library is used (richer shrinking/reporting); this module
degrades gracefully to a deterministic sweep over the strategy value space so
the tier-1 suite runs in minimal containers:

* each strategy contributes its full value list;
* the cartesian product is enumerated in a fixed order and subsampled evenly
  down to ``max_examples`` (default 16) — deterministic, no RNG;
* both decorator orders (@given above @settings and vice versa) work, as in
  hypothesis.

Usage (top of a test module)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # minimal container
        from hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import itertools

__all__ = ["given", "settings", "st", "strategies"]

_DEFAULT_EXAMPLES = 16


class _SampledFrom:
    def __init__(self, values):
        self.values = list(values)


class _Strategies:
    @staticmethod
    def sampled_from(values):
        return _SampledFrom(values)

    @staticmethod
    def booleans():
        return _SampledFrom([False, True])

    @staticmethod
    def integers(min_value, max_value):
        return _SampledFrom(range(min_value, max_value + 1))


st = strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._hf_max_examples = max_examples
        return fn
    return deco


def _subsample(combos: list, cap: int) -> list:
    if len(combos) <= cap:
        return combos
    step = len(combos) / cap
    return [combos[int(i * step)] for i in range(cap)]


def given(*arg_strats, **kw_strats):
    strats = list(arg_strats) + list(kw_strats.values())
    names = list(kw_strats)

    def deco(fn):
        # zero-arg wrapper (not functools.wraps: __wrapped__ would make
        # pytest read the original signature and hunt for fixtures)
        def run():
            cap = getattr(run, "_hf_max_examples",
                          getattr(fn, "_hf_max_examples", _DEFAULT_EXAMPLES))
            combos = list(itertools.product(*(s.values for s in strats)))
            for combo in _subsample(combos, cap):
                pos = combo[:len(arg_strats)]
                kws = dict(zip(names, combo[len(arg_strats):]))
                fn(*pos, **kws)
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run._hf_max_examples = getattr(fn, "_hf_max_examples", None) \
            or _DEFAULT_EXAMPLES
        return run
    return deco
