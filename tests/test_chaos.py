"""Chaos hardening: deterministic multi-seam fault injection, numeric
quarantine, clock-skew degradation, invariant auditing, and crash-safe
snapshot/restore.

Every test drives the REAL scheduler/engine (gather mode where paged —
the bitwise parity bar) under a seeded :class:`FaultPlan`; the green-path
runs set ``audit_interval=1`` so each tick also proves the auditor quiet.
The auditor test corrupts live state on purpose and checks the raised
``AuditError`` names the right invariant — then repairs the corruption
and re-audits clean, proving the corruption (not ambient state) was the
trigger."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import ServeConfig, get_config
from repro.models import transformer as tfm
from repro.serve import audit, faults
from repro.serve.engine import Engine, Request, RequestStatus
from repro.serve.frontend import PriorityScheduler

KEY = jax.random.PRNGKey(0)

CFG = dataclasses.replace(get_config("gemma-2b").reduced(), vocab_size=64,
                          num_layers=2, d_ff=64, capacity_factor=64.0)


def _engine(scfg: ServeConfig, cfg=CFG):
    params = tfm.init_params(cfg, KEY)
    sp = tfm.serve_params(params, cfg)
    return Engine(cfg, sp, scfg), sp


class TickClock:
    """Deterministic fake clock: advances ``dt`` on every call."""

    def __init__(self, dt: float = 0.0, t0: float = 0.0):
        self.t = t0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


def _solo_want(sp, prompts, max_new, *, prefill_chunk=32, max_seq_len=32):
    """Unconstrained solo greedy runs — the parity oracle."""
    ref = Engine(CFG, sp, ServeConfig(max_seq_len=max_seq_len, batch_size=1,
                                      prefill_chunk=prefill_chunk))
    want = {}
    for i, p in enumerate(prompts):
        ref.reset()
        want[i] = np.asarray(ref.generate(np.asarray(p)[None, :], max_new)[0])
    return want


# ---------------------------------------------------------------------------
# FaultPlan: grammar, determinism, precedence
# ---------------------------------------------------------------------------

def test_fault_plan_parse_all_seams():
    plan = faults.FaultPlan.parse(
        "alloc@3, alloc@7, prefill@1, poison@5:2, poison@9, "
        "clock+1.5@4, slow+0.25@6, torn@2, flip@4, fsync@1, fsync@3")
    assert plan.alloc == frozenset({3, 7})
    assert plan.prefill == frozenset({1})
    assert plan.poison == {5: 2, 9: 0}
    assert plan.clock == {4: 1.5}
    assert plan.slow == {6: 0.25}
    assert plan.torn == frozenset({2})
    assert plan.flip == frozenset({4})
    assert plan.fsync == frozenset({1, 3})
    assert plan.needs_clock
    assert not faults.FaultPlan.parse("alloc@1").needs_clock
    assert not faults.FaultPlan.parse("torn@1,flip@2,fsync@3").needs_clock
    for bad in ("gremlin@3", "alloc@x", "poison@", "clock+-2@3", "clock+1",
                "torn@x", "flip@", "fsync@1.5"):
        with pytest.raises(ValueError, match="fault plan"):
            faults.FaultPlan.parse(bad)


def test_fault_plan_seam_hooks_fire_once_and_tally():
    plan = faults.FaultPlan.parse("prefill@2,poison@3:5,clock+2@4,slow+1@6")
    assert not plan.take_prefill() and plan.take_prefill()   # calls 1, 2
    assert not plan.take_prefill()                           # fires once
    assert plan.poison_row(2, 3) is None
    assert plan.poison_row(3, 3) == 2                        # 5 % 3
    assert plan.poison_row(3, 0) is None                     # nothing active
    assert plan.tick_start_skew(4) == 2.0 and plan.tick_start_skew(5) == 0.0
    assert plan.tick_end_skew(6) == 1.0
    assert plan.fired == {"alloc": 0, "prefill": 1, "poison": 1,
                          "clock": 1, "slow": 1, "torn": 0, "flip": 0,
                          "fsync": 0}
    # alloc ordinals compose onto an existing injector: both keep firing
    inj = plan2_inj = faults.FaultPlan.parse("alloc@4").chain_alloc(
        lambda call, n: call == 2)
    assert not inj(1, 1) and inj(2, 1) and not inj(3, 1) and inj(4, 1)
    assert faults.FaultPlan.parse("prefill@1").chain_alloc(plan2_inj) \
        is plan2_inj                    # no alloc events: injector untouched


def test_fault_plan_random_is_deterministic_and_replayable():
    a, b = faults.FaultPlan.random(7), faults.FaultPlan.random(7)
    assert a.spec == b.spec
    assert a.spec != faults.FaultPlan.random(8).spec
    replay = faults.FaultPlan.parse(a.spec)       # printable spec round-trips
    assert (replay.alloc, replay.prefill, replay.poison, replay.clock,
            replay.slow, replay.torn, replay.flip, replay.fsync) == \
        (a.alloc, a.prefill, a.poison, a.clock, a.slow, a.torn, a.flip,
         a.fsync)
    assert a.alloc and a.prefill and a.poison and a.clock and a.slow
    assert a.torn and a.flip and a.fsync          # disk seams covered too
    assert all(2 <= t <= 64 for t in
               list(a.poison) + list(a.clock) + list(a.slow))


def test_env_fault_plan_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert faults.env_fault_plan("") is None
    assert faults.env_fault_plan("alloc@5").alloc == frozenset({5})
    monkeypatch.setenv("REPRO_FAULTS", "prefill@2")
    plan = faults.env_fault_plan("alloc@5")       # env outranks scfg
    assert plan.prefill == frozenset({2}) and not plan.alloc


# ---------------------------------------------------------------------------
# Numeric quarantine: poisoned logits fail ONE request, the rest are bitwise
# ---------------------------------------------------------------------------

def test_poison_quarantines_one_request_rest_bitwise():
    scfg = ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=6, paged_attn="gather",
                       fault_plan="poison@3:1", audit_interval=1)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 64, 8).astype(np.int32) for _ in range(3)]
    max_new = 8
    want = _solo_want(sp, prompts, max_new)
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=max_new))
    done = {r.rid: r for r in sched.run()}
    assert len(done) == 3 and sched.fault_plan.fired["poison"] == 1
    bad = done[1]                       # poison@3:1 -> active row 1 = slot 1
    assert bad.status is RequestStatus.FAILED_NUMERIC
    assert "non-finite" in bad.error and "quarantined" in bad.error
    assert 0 < len(bad.generated) < max_new      # partial output kept ...
    np.testing.assert_array_equal(                # ... and a bitwise PREFIX
        np.asarray(bad.generated), want[1][:len(bad.generated)])
    for rid in (0, 2):                  # the rest of the batch: untouched
        assert done[rid].status is RequestStatus.OK
        np.testing.assert_array_equal(np.asarray(done[rid].generated),
                                      want[rid])
    assert sched.stats["quarantined"] == 1
    assert e.pool.free_count == e.pool.num_blocks    # quarantine freed blocks
    assert e.pool.live_refs == 0


# ---------------------------------------------------------------------------
# Transient prefill fault: rolled back, retried, fault-free parity
# ---------------------------------------------------------------------------

def test_prefill_fault_is_transient_and_parity_preserving():
    scfg = ServeConfig(max_seq_len=32, batch_size=2, kv_block_size=8,
                       kv_num_blocks=8, paged_attn="gather",
                       fault_plan="prefill@1", audit_interval=1)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(12)
    prompts = [rng.integers(1, 64, 9).astype(np.int32) for _ in range(2)]
    want = _solo_want(sp, prompts, 6)
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=6))
    done = {r.rid: r for r in sched.run()}
    assert sched.fault_plan.fired["prefill"] == 1
    assert sched.stats["prefill_faults"] == 1
    for i in range(2):                  # the faulted admission retried clean
        assert done[i].status is RequestStatus.OK
        np.testing.assert_array_equal(np.asarray(done[i].generated), want[i])
    assert e.pool.free_count == e.pool.num_blocks
    assert e.pool.live_refs == 0


def test_faulted_admission_rollback_keeps_position_mirror():
    """Regression (found by the PR-10 telemetry chaos soak): the
    PrefillFault/BlockPoolExhausted rollback in ``_admit`` calls
    ``free_slot`` — which zeroes the slot's DEVICE position — but used to
    leave the host mirror at its garbage-crept value, so the two stayed
    offset forever.  The fault must hit a slot whose idle position has
    already crept (decode ticks ran first); per-tick auditing then proves
    the mirror exact through the rollback."""
    scfg = ServeConfig(max_seq_len=32, batch_size=2, kv_block_size=8,
                       kv_num_blocks=8, paged_attn="gather",
                       fault_plan="prefill@2", audit_interval=1)
    e, _ = _engine(scfg)
    sched = PriorityScheduler(e)
    rng = np.random.default_rng(12)
    sched.submit(Request(rid=0, prompt=rng.integers(1, 64, 9).astype(
        np.int32), max_new=12))
    finished: list = []
    for _ in range(4):          # idle slot 1's device pos creeps with each
        sched.tick(finished)    # batched step (host mirror tracks it)
    sched.submit(Request(rid=1, prompt=rng.integers(1, 64, 9).astype(
        np.int32), max_new=4))
    done = {r.rid: r for r in sched.run()}
    for r in finished:
        done[r.rid] = r
    assert sched.fault_plan.fired["prefill"] == 1
    assert all(done[i].status is RequestStatus.OK for i in range(2))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(e.cache["pos"])), np.asarray(sched._pos))
    audit.audit_scheduler(sched)


# ---------------------------------------------------------------------------
# Clock faults: jumps expire deadlines, slow ticks trip hopeless shedding
# ---------------------------------------------------------------------------

def test_clock_jump_times_out_running_request_gracefully():
    scfg = ServeConfig(max_seq_len=32, batch_size=1,
                       fault_plan="clock+100@3", audit_interval=1)
    e, _ = _engine(scfg)
    sched = PriorityScheduler(e, clock=TickClock(0.01))
    sched.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                         max_new=25, deadline_s=50.0))
    done = sched.run()                  # must terminate, not raise or hang
    assert len(done) == 1 and done[0].status is RequestStatus.TIMEOUT
    assert "deadline exceeded" in done[0].error
    assert 0 < len(done[0].generated) < 25       # partial output preserved
    assert sched.fault_plan.fired["clock"] == 1
    assert sched.stats["timeouts"] == 1


def test_slow_tick_inflates_ema_and_sheds_hopeless_deadline():
    scfg = ServeConfig(max_seq_len=32, batch_size=1,
                       fault_plan="slow+40@1", audit_interval=1)
    e, _ = _engine(scfg)
    sched = PriorityScheduler(e, clock=TickClock(0.01))
    sched.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                         max_new=2))
    finished: list = []
    while not sched.idle:
        sched.tick(finished)
    assert finished[0].status is RequestStatus.OK
    assert sched.fault_plan.fired["slow"] == 1
    assert sched._tick_ema is not None and sched._tick_ema > 10.0
    # the contended-host EMA now says a 5s deadline cannot land a token
    sched.submit(Request(rid=1, prompt=np.arange(1, 5, dtype=np.int32),
                         max_new=2, deadline_s=5.0))
    sched.tick(finished)
    assert finished[-1].rid == 1
    assert finished[-1].status is RequestStatus.TIMEOUT
    assert "hopeless" in finished[-1].error
    assert sched.stats["shed"] == 1


# ---------------------------------------------------------------------------
# The auditor: catches deliberate corruption, names the invariant, and is
# quiet again once the corruption is repaired
# ---------------------------------------------------------------------------

def test_auditor_catches_corruptions_and_names_invariants():
    scfg = ServeConfig(max_seq_len=32, batch_size=2, kv_block_size=8,
                       kv_num_blocks=6, paged_attn="gather")
    e, _ = _engine(scfg)
    sched = PriorityScheduler(e)
    sched.submit(Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                         max_new=4))
    done = sched.run()
    assert done[0].status is RequestStatus.OK
    audit.audit_scheduler(sched)        # healthy post-run state: silent

    def expect(invariant):
        with pytest.raises(audit.AuditError) as ei:
            audit.audit_scheduler(sched)
        assert ei.value.invariant == invariant
        assert "state dump" in str(ei.value) and ei.value.state
        return ei.value

    # I4: refcount>0 for a block sitting on the free list
    bid = e.pool._free[0]
    e.pool._ref[bid] += 1
    expect("I4")
    e.pool._ref[bid] -= 1
    # I1: a slot claims a reference the pool never granted
    e._slot_blocks[0].append(bid)
    expect("I1")
    e._slot_blocks[0].pop()
    # I3: hash registry bijection broken (warm block re-pointed)
    warm_bid = next(iter(e.pool._warm))
    h = e.pool._bid_to_hash[warm_bid]
    e.pool._bid_to_hash[warm_bid] = b"\x00" * len(h)
    expect("I3")
    e.pool._bid_to_hash[warm_bid] = h
    # I6: host position mirror drifts from the device cache
    sched._pos[0] += 1
    expect("I6")
    sched._pos[0] -= 1
    # I7: a terminal request still scheduled
    sched.queue.append(done[0])
    expect("I7")
    sched.queue.clear()
    audit.audit_scheduler(sched)        # every repair verified: silent again


# ---------------------------------------------------------------------------
# Seeded chaos soak (compact; the full randomized soak is `bench --only
# chaos`): every seam fires, every request terminal, OK parity bitwise
# ---------------------------------------------------------------------------

def test_chaos_soak_all_seams_terminal_and_parity():
    spec = "alloc@4,prefill@2,poison@6:1,clock+0.6@9,slow+0.8@5"
    scfg = ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=9, prefill_chunk=8, paged_attn="gather",
                       overcommit=1.5, max_prefill_tokens_per_tick=16,
                       fault_plan=spec, audit_interval=1)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, 64, 9).astype(np.int32) for _ in range(5)]
    max_new = 12
    want = _solo_want(sp, prompts, max_new, prefill_chunk=8)
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=max_new,
                             priority=i % 3,
                             deadline_s=300.0 if i == 0 else None))
    done = {r.rid: r for r in sched.run()}     # no wedge: run() returned
    assert sorted(done) == [0, 1, 2, 3, 4]     # every request terminal
    fired = sched.fault_plan.fired
    assert fired["prefill"] == 1               # the seams actually fired
    assert fired["alloc"] + fired["poison"] + fired["clock"] \
        + fired["slow"] >= 2
    quarantined = [r for r in done.values()
                   if r.status is RequestStatus.FAILED_NUMERIC]
    assert len(quarantined) == fired["poison"] <= 1
    for r in done.values():
        assert r.status in (RequestStatus.OK, RequestStatus.FAILED_NUMERIC)
        if r.status is RequestStatus.OK:       # bitwise vs fault-free solo
            assert len(r.generated) == max_new
            np.testing.assert_array_equal(np.asarray(r.generated),
                                          want[r.rid])
        else:                                  # quarantine: bitwise PREFIX
            np.testing.assert_array_equal(
                np.asarray(r.generated), want[r.rid][:len(r.generated)])
    assert e.pool.free_count == e.pool.num_blocks    # zero leaks under chaos
    assert e.pool.live_refs == 0
    audit.audit_scheduler(sched)


# ---------------------------------------------------------------------------
# Crash-safe snapshot/restore: bitwise-continuous resume on a fresh engine
# ---------------------------------------------------------------------------

def test_snapshot_restore_resumes_inflight_bitwise():
    scfg = ServeConfig(max_seq_len=32, batch_size=3, kv_block_size=8,
                       kv_num_blocks=12, paged_attn="gather",
                       audit_interval=1)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(14)
    prompts = [rng.integers(1, 64, 9).astype(np.int32) for _ in range(3)]
    max_new = 20
    want = _solo_want(sp, prompts, max_new)
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=max_new))
    finished: list = []
    for _ in range(5):                  # mid-serve: everyone inflight
        sched.tick(finished)
    assert not finished and all(s is not None for s in sched.slots)
    progress = {r.rid: len(r.generated) for r in sched.slots}
    assert all(0 < n < max_new for n in progress.values())
    snap = sched.snapshot()
    assert len(snap["inflight"]) == 3 and not snap["queue"]
    assert len(snap["registered"]) == 3        # one full prompt block each
    assert snap["kv"]                          # ... with device KV exported

    # "crash": the old engine/scheduler are simply abandoned.  A fresh
    # engine (same params/config — the fingerprint) restores the snapshot.
    e2 = Engine(CFG, sp, scfg)
    sched2 = PriorityScheduler(e2)
    sched2.submit(Request(rid=9, prompt=np.arange(1, 5, dtype=np.int32),
                          max_new=2))
    with pytest.raises(RuntimeError, match="idle"):
        sched2.restore(snap)                   # guard: restore is boot-time
    sched2.queue.clear()
    with pytest.raises(ValueError, match="fingerprint"):
        sched2.restore({**snap, "fingerprint": ("other-model", 32, 3, None)})
    sched2.restore(snap)
    assert sched2.stats["restored"] == 3
    done = {r.rid: r for r in sched2.run()}
    assert sorted(done) == [0, 1, 2]
    for rid, r in done.items():
        assert r.status is RequestStatus.OK
        assert len(r.generated) == max_new
        # the resumed stream continues bitwise where the crash cut it
        np.testing.assert_array_equal(np.asarray(r.generated), want[rid])
    # resume was tail-only: every request's full prompt block warm-hit
    # instead of re-prefilling (8 tokens x 3 requests)
    assert e2.pool.stats["hit_tokens"] == 24
    assert e2.pool.stats["warm_hit_blocks"] == 3
    assert e2.pool.free_count == e2.pool.num_blocks
    assert e2.pool.live_refs == 0
    audit.audit_scheduler(sched2)


def test_snapshot_restore_dense_engine_full_reprefill():
    """Non-paged engines snapshot too — no block KV to export, so resume
    is a full re-prefill of prompt+generated: slower, same tokens."""
    scfg = ServeConfig(max_seq_len=32, batch_size=2, audit_interval=1)
    e, sp = _engine(scfg)
    rng = np.random.default_rng(15)
    prompts = [rng.integers(1, 64, n).astype(np.int32) for n in (6, 7)]
    max_new = 12
    want = _solo_want(sp, prompts, max_new)
    sched = PriorityScheduler(e)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p.copy(), max_new=max_new))
    finished: list = []
    for _ in range(3):
        sched.tick(finished)
    assert not finished
    snap = sched.snapshot()
    assert len(snap["inflight"]) == 2 and "registered" not in snap
    e2 = Engine(CFG, sp, scfg)
    sched2 = PriorityScheduler(e2)
    sched2.restore(snap)
    done = {r.rid: r for r in sched2.run()}
    assert sched2.stats["restored"] == 2
    for rid in (0, 1):
        assert done[rid].status is RequestStatus.OK
        np.testing.assert_array_equal(np.asarray(done[rid].generated),
                                      want[rid])
