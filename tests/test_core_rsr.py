"""Core RSR/RSR++ correctness: paper worked examples + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: deterministic sweep
    from hypothesis_fallback import given, settings, st

from repro.core import (bin_matrix, decompose_ternary, fold_bin_product,
                        index_nbytes, optimal_k_rsr, optimal_k_rsrpp,
                        preprocess_binary, preprocess_ternary,
                        preprocess_ternary_direct, random_binary,
                        random_ternary, recompose_ternary, rsr_matmul_binary,
                        rsr_matmul_ternary, rsr_matmul_ternary_direct,
                        segmented_sum, tern_matrix)

# ---- paper §3.1 example -----------------------------------------------------

PAPER_B = jnp.array([
    [0, 1, 1, 1, 0, 1],
    [0, 0, 0, 1, 1, 1],
    [0, 1, 1, 1, 1, 0],
    [1, 1, 0, 0, 1, 0],
    [0, 0, 1, 1, 0, 1],
    [0, 0, 0, 0, 1, 0]], dtype=jnp.int8)


def test_paper_example_blocking_permutation_segmentation():
    idx = preprocess_binary(PAPER_B, 2)
    assert idx.num_blocks == 3
    # block 1 row codes: rows (01,00,01,11,00,00) -> values (1,0,1,3,0,0)
    np.testing.assert_array_equal(idx.codes[0], [1, 0, 1, 3, 0, 0])
    # σ (Example 3.3, 0-indexed): rows in sorted order = [2,5,6,1,3,4] - 1
    np.testing.assert_array_equal(idx.perm[0], [1, 4, 5, 0, 2, 3])
    # Full Segmentation (paper Fig 2, 0-indexed + sentinel): [0,3,5,5,6]
    np.testing.assert_array_equal(idx.seg[0], [0, 3, 5, 5, 6])


def test_paper_example_product():
    """v·B for the paper's §3 matrix — every impl, RSR and RSR++."""
    v = jnp.array([3., 2., 4., 5., 9., 1.])
    want = v @ PAPER_B.astype(jnp.float32)
    idx = preprocess_binary(PAPER_B, 2)
    for impl in ("segments", "scatter", "onehot"):
        for pp in (False, True):
            got = rsr_matmul_binary(v, idx, impl=impl, plus_plus=pp)
            np.testing.assert_allclose(got, want, rtol=1e-6)


def test_segmented_sum_matches_definition():
    """Def 4.1 directly: u[j] = Σ v_π over segment j (incl. empty segments)."""
    idx = preprocess_binary(PAPER_B, 2)
    v = jnp.array([3., 2., 4., 5., 9., 1.])
    u = segmented_sum(v, idx.perm, idx.seg)
    vp = np.asarray(v)[np.asarray(idx.perm[0])]
    want = [vp[0:3].sum(), vp[3:5].sum(), 0.0, vp[5:6].sum()]
    np.testing.assert_allclose(u[0], want, rtol=1e-6)


# ---- Prop 2.1 ---------------------------------------------------------------

def test_ternary_decomposition_roundtrip():
    a = random_ternary(jax.random.PRNGKey(0), (33, 17))
    b1, b2 = decompose_ternary(a)
    assert set(np.unique(b1)) <= {0, 1} and set(np.unique(b2)) <= {0, 1}
    np.testing.assert_array_equal(recompose_ternary(b1, b2), a)
    assert not bool(jnp.any((b1 == 1) & (b2 == 1)))


# ---- Bin_[k] / Tern_[k] -----------------------------------------------------

def test_bin_matrix_enumerates_all_patterns():
    for k in range(1, 8):
        b = np.asarray(bin_matrix(k))
        assert b.shape == (2 ** k, k)
        vals = (b * (2 ** np.arange(k - 1, -1, -1))).sum(1)
        np.testing.assert_array_equal(vals, np.arange(2 ** k))


def test_tern_matrix_enumerates_all_patterns():
    for k in range(1, 6):
        t = np.asarray(tern_matrix(k))
        assert t.shape == (3 ** k, k)
        digits = np.where(t == -1, 2, t)
        vals = (digits * (3 ** np.arange(k - 1, -1, -1))).sum(1)
        np.testing.assert_array_equal(vals, np.arange(3 ** k))


# ---- Algorithm 3 (RSR++) ----------------------------------------------------

@given(st.sampled_from([1, 3, 5, 7]), st.sampled_from([1, 4]))
@settings(max_examples=8, deadline=None)
def test_fold_equals_bin_product(k, rows):
    u = jax.random.normal(jax.random.PRNGKey(k * 131 + rows), (rows, 2 ** k))
    np.testing.assert_allclose(fold_bin_product(u), u @ bin_matrix(k),
                               rtol=1e-4, atol=1e-4)


def test_fold_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        fold_bin_product(jnp.ones((4, 7)))


# ---- property tests: every implementation == naive matmul -------------------

@given(n=st.sampled_from([3, 8, 16, 33]), m=st.sampled_from([1, 7, 24]),
       k=st.sampled_from([1, 2, 4]), batch=st.sampled_from([1, 2]),
       impl=st.sampled_from(["segments", "scatter", "onehot"]))
@settings(max_examples=15, deadline=None)
def test_binary_rsr_equals_naive(n, m, k, batch, impl):
    key = jax.random.PRNGKey(n * 7919 + m * 131 + k)
    b = random_binary(key, (n, m))
    v = jax.random.normal(jax.random.fold_in(key, 1), (batch, n))
    idx = preprocess_binary(b, k)
    want = v @ b.astype(jnp.float32)
    got = rsr_matmul_binary(v, idx, impl=impl, plus_plus=(k <= 4))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(n=st.sampled_from([4, 9, 32]), m=st.sampled_from([2, 11, 20]),
       k=st.sampled_from([1, 3, 5]),
       impl=st.sampled_from(["segments", "scatter", "onehot"]))
@settings(max_examples=15, deadline=None)
def test_ternary_rsr_equals_naive(n, m, k, impl):
    key = jax.random.PRNGKey(n * 104729 + m * 31 + k)
    a = random_ternary(key, (n, m))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, n))
    want = v @ a.astype(jnp.float32)
    got = rsr_matmul_ternary(v, preprocess_ternary(a, k), impl=impl)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    got_d = rsr_matmul_ternary_direct(v, preprocess_ternary_direct(a, k),
                                      impl=impl)
    np.testing.assert_allclose(got_d, want, rtol=2e-4, atol=2e-4)


@given(n=st.sampled_from([8, 24]), dt=st.sampled_from(["float32", "bfloat16"]))
@settings(max_examples=4, deadline=None)
def test_rsr_dtype_support(n, dt):
    a = random_ternary(jax.random.PRNGKey(n), (n, n))
    v = jax.random.normal(jax.random.PRNGKey(n + 1), (n,)).astype(dt)
    got = rsr_matmul_ternary(v, preprocess_ternary(a, 3))
    want = v.astype(jnp.float32) @ a.astype(jnp.float32)
    tol = 5e-2 if dt == "bfloat16" else 1e-4
    np.testing.assert_allclose(got.astype(jnp.float32), want, rtol=tol,
                               atol=tol)


# ---- complexity knobs -------------------------------------------------------

def test_optimal_k_grows_with_n():
    ks = [optimal_k_rsrpp(2 ** e) for e in range(8, 17, 2)]
    assert ks == sorted(ks)
    assert optimal_k_rsr(2 ** 13) >= 6


def test_index_space_below_dense_float():
    """Theorem 3.6 / Fig 5: index bytes << n·m float bytes for large n."""
    n = 1024
    a = random_ternary(jax.random.PRNGKey(0), (n, n))
    k = optimal_k_rsrpp(n)
    idx = preprocess_ternary(a, k)
    dense_f32 = n * n * 4
    assert index_nbytes(idx, "paper") < dense_f32
    # the packed-codes form beats even int8 dense storage
    assert index_nbytes(idx, "codes") < n * n


def test_gradients_flow_through_rsr():
    """The index is static; d(v·A)/dv must equal Aᵀ row sums."""
    a = random_ternary(jax.random.PRNGKey(5), (16, 12))
    idx = preprocess_ternary(a, 3)
    g = jax.grad(lambda v: rsr_matmul_ternary(v, idx).sum())(
        jnp.ones((16,)))
    np.testing.assert_allclose(g, a.astype(jnp.float32).sum(1), rtol=1e-5)
