"""Backend dispatch + kernel parity: every serve-path backend, mode, and
layout must agree with the core oracles (segments / scatter / onehot)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (pack_code_words, preprocess_binary,
                        preprocess_ternary, preprocess_ternary_direct,
                        random_binary, random_ternary, rsr_matmul_binary,
                        rsr_matmul_ternary, rsr_matmul_ternary_direct,
                        unpack_code_words)
from repro.core.preprocess import code_traffic_bits_per_weight
from repro.kernels import rsr_matmul_kernel
from repro.kernels.dispatch import (AUTOTUNE_TABLE, resolve_n_out,
                                    rsr_serve_linear, rsr_serve_matmul,
                                    select_backend, select_tiles)
from repro.models.modules import (abstract_serve_linear, rsr_linear_apply,
                                  serve_linear_params)
from repro.config import ModelConfig

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="t", family="dense", rsr_k=5)


# ---------------------------------------------------------------------------
# Kernel vs core oracles across modes / shapes / dtypes (satellite: parity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["binary", "ternary_fused", "ternary_direct"])
@pytest.mark.parametrize("n,m", [(256, 64), (300, 70), (130, 17)])
def test_kernel_matches_oracles_all_modes(mode, n, m):
    """rsr_matmul_kernel == segments == scatter == onehot, including shapes
    that are not tile multiples (padding correctness)."""
    key = jax.random.fold_in(KEY, n * m + len(mode))
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, n))
    if mode == "binary":
        w = random_binary(key, (n, m))
        idx = preprocess_binary(w, 4)
        oracle = lambda impl: rsr_matmul_binary(x, idx, impl=impl)
    elif mode == "ternary_fused":
        w = random_ternary(key, (n, m))
        idx = preprocess_ternary(w, 4)
        oracle = lambda impl: rsr_matmul_ternary(x, idx, impl=impl)
    else:
        w = random_ternary(key, (n, m))
        idx = preprocess_ternary_direct(w, 5)
        oracle = lambda impl: rsr_matmul_ternary_direct(x, idx, impl=impl)
    got = rsr_matmul_kernel(x, idx)
    want = x @ w.astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    for impl in ("segments", "scatter", "onehot"):
        np.testing.assert_allclose(got, oracle(impl), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["ternary_fused", "ternary_direct"])
def test_kernel_dtypes_all_modes(mode, dtype):
    a = random_ternary(jax.random.fold_in(KEY, 17), (256, 60))
    x = jax.random.normal(jax.random.fold_in(KEY, 18), (4, 256)).astype(dtype)
    idx = (preprocess_ternary(a, 6) if mode == "ternary_fused"
           else preprocess_ternary_direct(a, 5))
    got = rsr_matmul_kernel(x, idx)
    want = x.astype(jnp.float32) @ a.astype(jnp.float32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# Packed-code streaming
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(256, 40), (257, 37), (96, 5)])
def test_packed_kernel_matches_unpacked(n, m):
    a = random_ternary(jax.random.fold_in(KEY, n + m), (n, m))
    idx = preprocess_ternary_direct(a, 5)
    packed = pack_code_words(idx.codes)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, n))
    y_packed = rsr_serve_matmul(x, idx.codes, k=5, packed=packed, n_out=m,
                                backend="pallas_interpret")
    y_plain = rsr_serve_matmul(x, idx.codes, k=5, n_out=m,
                               backend="pallas_interpret")
    y_scatter = rsr_serve_matmul(x, idx.codes, k=5, n_out=m,
                                 backend="scatter")
    want = x @ a.astype(jnp.float32)
    np.testing.assert_allclose(y_packed, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_packed, y_plain, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_packed, y_scatter, rtol=1e-4, atol=1e-4)


def test_pack_roundtrip_uint16():
    codes = jax.random.randint(KEY, (3, 41), 0, 3 ** 6).astype(jnp.uint16)
    words = pack_code_words(codes)
    assert words.dtype == jnp.uint32 and words.shape == (3, 21)
    np.testing.assert_array_equal(unpack_code_words(words, 41, 16), codes)


def test_packed_traffic_within_budget():
    """Acceptance: the packed-code kernel moves ≤ 2 bits/weight of codes."""
    assert code_traffic_bits_per_weight(5) == pytest.approx(1.6)
    assert code_traffic_bits_per_weight(5) <= 2.0


# ---------------------------------------------------------------------------
# Dispatch: backend resolution, tiles, epilogue, n_out
# ---------------------------------------------------------------------------

def test_select_backend_resolution(monkeypatch):
    assert select_backend("scatter") == "scatter"
    monkeypatch.setenv("REPRO_RSR_BACKEND", "scatter")
    assert select_backend() == "scatter"
    # operator env var overrides a config-pinned backend; explicit arg wins
    assert select_backend(None, "pallas") == "scatter"
    assert select_backend("pallas_interpret", "pallas") == "pallas_interpret"
    monkeypatch.delenv("REPRO_RSR_BACKEND")
    assert select_backend(None, "pallas") == "pallas"
    assert select_backend() in ("pallas", "pallas_interpret")
    with pytest.raises(ValueError):
        select_backend("cuda")


def test_select_tiles_regimes():
    tb, tblk, tn = select_tiles(1, 800, 4096)      # decode: min batch tile
    assert tb == 8 and tn == 512
    tb2, _, _ = select_tiles(256, 800, 4096)       # prefill: wide batch tile
    assert tb2 == AUTOTUNE_TABLE[-1][2]
    tb3, tblk3, tn3 = select_tiles(2, 13, 64)      # smoke model: clamped
    assert tb3 == 8 and tblk3 == 8 and tn3 == 128


def test_autotune_cache_roundtrip(tmp_path):
    """Satellite: autotune(..., write=) persists measured tiles; the cache
    loads back over the static table and select_tiles honors it."""
    from repro.kernels import dispatch
    snapshot = dict(dispatch.TUNED_TILES)
    try:
        path = tmp_path / "autotune_cache.json"
        res = dispatch.autotune(4, 128, 40, reps=1,
                                backend="pallas_interpret",
                                candidates=((8, 8, 128), (8, 8, 256)),
                                write=str(path))
        assert path.exists()
        assert res["key"][0] == "decode"           # b=4 -> decode regime
        assert dispatch.TUNED_TILES[res["key"]] == res["tiles"]
        dispatch.TUNED_TILES.clear()
        loaded = dispatch.load_autotune_cache(str(path))
        assert loaded >= 1
        assert dispatch.TUNED_TILES[res["key"]] == tuple(res["tiles"])
        # select_tiles prefers the tuned entry (shape-clamped as usual)
        nb = -(-40 // 5)                           # 8 blocks -> bucket 8
        got = dispatch.select_tiles(4, nb, 128)
        tb, tblk, tn = res["tiles"]
        assert got == (min(tb, 8), min(tblk, 8), min(tn, 128))
        # a different bucket still falls back to the static regime row
        assert dispatch.select_tiles(256, 800, 4096)[0] == \
            AUTOTUNE_TABLE[-1][2]
    finally:
        dispatch.TUNED_TILES.clear()
        dispatch.TUNED_TILES.update(snapshot)


def test_autotune_cache_default_path_is_cwd_independent(tmp_path,
                                                        monkeypatch):
    """Satellite regression: the import-time load used to resolve
    autotune_cache.json against the CWD, so a stray cache file in an
    unrelated working directory silently steered kernel tiles.  The
    default must be repo-anchored ($REPRO_AUTOTUNE_CACHE outranks it)."""
    from repro.kernels import dispatch
    assert os.path.isabs(dispatch.DEFAULT_AUTOTUNE_CACHE)
    # a stray cache in the CWD must NOT be picked up by a default load
    stray = {"schema": "autotune_cache_v1", "host_backend": None,
             "entries": [{"regime": "decode", "nb_bucket": 4096,
                          "n_bucket": 4096, "tiles": [1, 1, 128]}]}
    import json as _json
    (tmp_path / "autotune_cache.json").write_text(_json.dumps(stray))
    monkeypatch.chdir(tmp_path)
    snapshot = dict(dispatch.TUNED_TILES)
    try:
        dispatch.load_autotune_cache(clear=True)
        assert ("decode", 4096, 4096) not in dispatch.TUNED_TILES
        # the env var still routes to an explicit file (and logs the load)
        monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV,
                           str(tmp_path / "autotune_cache.json"))
        loaded = dispatch.load_autotune_cache(clear=True)
        assert loaded == 1
        assert dispatch.TUNED_TILES[("decode", 4096, 4096)] == (1, 1, 128)
    finally:
        dispatch.TUNED_TILES.clear()
        dispatch.TUNED_TILES.update(snapshot)


@pytest.mark.parametrize("backend", ["pallas_interpret", "scatter"])
def test_fused_epilogue_scale_bias(backend):
    a = random_ternary(jax.random.fold_in(KEY, 5), (128, 37))
    sp = serve_linear_params({"w": jnp.asarray(a, jnp.float32) * 0.02},
                             cfg=CFG)
    sp["b"] = jax.random.normal(jax.random.fold_in(KEY, 6), (37,))
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 4, 128))
    got = rsr_serve_linear(sp, x, cfg=CFG, backend=backend)
    # reconstruct the dequantized weight the serve params encode
    from repro.core.ternary import absmean_quantize
    wt, gamma = absmean_quantize(jnp.asarray(a, jnp.float32) * 0.02)
    want = (x @ wt) * gamma + sp["b"]
    assert got.shape == (2, 4, 37)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_n_out_marker_fixes_padded_width_bug():
    """Without a bias, n_out % k != 0 used to silently return padded columns;
    the explicit n_out marker restores the true width."""
    w = jax.random.normal(KEY, (64, 37))           # 37 % 5 != 0
    sp = serve_linear_params({"w": w}, cfg=CFG)
    assert "b" not in sp
    assert sp["n_out"].shape == (37, 0) and sp["n_out"].size == 0
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (3, 64))
    y = rsr_linear_apply(sp, x, cfg=CFG)
    assert y.shape == (3, 37)
    # resolution order: explicit arg > marker > bias > padded nb*k
    assert resolve_n_out(sp, 5, sp["codes"].shape[0]) == 37
    assert resolve_n_out(sp, 5, sp["codes"].shape[0], n_out=35) == 35
    assert resolve_n_out({}, 5, 8) == 40


def test_abstract_serve_linear_matches_real():
    """Dry-run abstract tree must mirror the real conversion exactly."""
    w = jax.random.normal(KEY, (96, 23))
    real = serve_linear_params({"w": w}, cfg=CFG)
    abstract = abstract_serve_linear(96, 23, cfg=CFG)
    assert set(real) == set(abstract)
    for name, s in abstract.items():
        assert real[name].shape == s.shape, name
        assert real[name].dtype == s.dtype, name


def test_backends_agree_under_jit_and_vmap():
    """MoE-style usage: dispatch under jax.vmap over an expert axis."""
    e, n, m = 3, 64, 16
    ws = jax.random.normal(KEY, (e, n, m))
    sp = jax.vmap(lambda w: serve_linear_params({"w": w}, cfg=CFG))(ws)
    xs = jax.random.normal(jax.random.fold_in(KEY, 11), (e, 2, n))
    outs = {}
    for backend in ("pallas_interpret", "scatter"):
        f = jax.vmap(lambda p, x: rsr_serve_linear(p, x, cfg=CFG, n_out=m,
                                                   backend=backend))
        outs[backend] = f({k: sp[k] for k in ("codes", "packed", "scale")},
                          xs)
    np.testing.assert_allclose(outs["pallas_interpret"], outs["scatter"],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Autotune cache validation (satellite: a bad file must fail loudly and
# must never clear or half-populate the tuned tables)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("payload", [
    ["not", "a", "dict"],
    {"schema": "autotune_cache_v1",
     "entries": [{"regime": "warp9", "nb_bucket": 8, "n_bucket": 128,
                  "tiles": [8, 8, 128]}]},                 # unknown regime
    {"schema": "autotune_cache_v1",
     "entries": [{"regime": "decode", "nb_bucket": 8, "n_bucket": 128,
                  "tiles": [8, 8]}]},                      # wrong tile arity
    {"schema": "autotune_cache_v1",
     "entries": [{"regime": "decode", "nb_bucket": 8, "n_bucket": 128,
                  "tiles": [8, -8, 128]}]},                # non-positive tile
    {"schema": "autotune_cache_v1",
     "entries": [{"regime": "decode", "nb_bucket": 0, "n_bucket": 128,
                  "tiles": [8, 8, 128]}]},                 # bad bucket
    {"schema": "autotune_cache_v1", "entries": [],
     "attn_entries": [{"regime": "prefill", "c_bucket": 32,
                       "tile_c": True}]},                  # bool is not int
])
def test_autotune_cache_rejects_malformed(tmp_path, payload):
    import json as _json
    from repro.kernels import dispatch
    path = tmp_path / "autotune_cache.json"
    path.write_text(_json.dumps(payload))
    with pytest.raises(dispatch.AutotuneCacheError):
        dispatch.load_autotune_cache(str(path))


def test_autotune_cache_bad_file_never_mutates_tables(tmp_path):
    """Validation runs BEFORE any table mutation: a file that is half
    valid must not clear the tables or apply its valid prefix."""
    import json as _json
    from repro.kernels import dispatch
    from repro.kernels.paged_attention import TUNED_ATTN_TILES
    good_then_bad = {
        "schema": "autotune_cache_v1", "host_backend": None,
        "entries": [
            {"regime": "decode", "nb_bucket": 8, "n_bucket": 128,
             "tiles": [8, 8, 128]},                        # valid
            {"regime": "decode", "nb_bucket": 8, "n_bucket": 256,
             "tiles": [8, 8, "wide"]},                     # invalid
        ]}
    path = tmp_path / "autotune_cache.json"
    path.write_text(_json.dumps(good_then_bad))
    snapshot = dict(dispatch.TUNED_TILES)
    sentinel = ("prefill", 9999, 9999)
    dispatch.TUNED_TILES[sentinel] = (128, 8, 256)
    attn_snapshot = dict(TUNED_ATTN_TILES)
    try:
        with pytest.raises(dispatch.AutotuneCacheError):
            dispatch.load_autotune_cache(str(path), clear=True)
        # the valid first entry was NOT applied, clear= did NOT run
        assert ("decode", 8, 128) not in dispatch.TUNED_TILES
        assert dispatch.TUNED_TILES[sentinel] == (128, 8, 256)
        assert TUNED_ATTN_TILES == attn_snapshot
        # corrupt JSON maps to the same named error
        path.write_text("{not json")
        with pytest.raises(dispatch.AutotuneCacheError):
            dispatch.load_autotune_cache(str(path))
    finally:
        dispatch.TUNED_TILES.clear()
        dispatch.TUNED_TILES.update(snapshot)


def test_validate_autotune_payload_returns_typed_entries():
    from repro.kernels import dispatch
    tuned, attn = dispatch.validate_autotune_payload({
        "schema": "autotune_cache_v1",
        "entries": [{"regime": "small", "nb_bucket": 16, "n_bucket": 512,
                     "tiles": [32, 8, 256]}],
        "attn_entries": [{"regime": "prefill", "c_bucket": 32,
                          "tile_c": 16}]})
    assert tuned == {("small", 16, 512): (32, 8, 256)}
    assert attn == {("prefill", 32): 16}
